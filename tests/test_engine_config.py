"""EngineConfig: construction-time validation, cross-field resolve()
downgrades, argparse routing, and the Engine deprecation shim."""

import argparse
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.serving import engine as engine_lib
from repro.serving.config import EngineConfig

ENC = EncodingConfig(enabled=True, backend="xla")


# ---- validation ------------------------------------------------------------

@pytest.mark.parametrize("kwargs,match", [
    (dict(decode_mode="turbo"), "decode_mode"),
    (dict(cache_mode="ring"), "cache_mode"),
    (dict(sample="nucleus"), "sample"),
    (dict(slots=0), "slots"),
    (dict(max_seq=0), "max_seq"),
    (dict(block_size=12), "block_size"),
    (dict(block_size=0), "block_size"),
    (dict(pool_pages=1), "pool_pages"),
    (dict(draft_k=-1), "draft_k"),
    (dict(token_budget=0), "token_budget"),
    (dict(slo_aging_steps=0), "slo_aging_steps"),
    (dict(max_queue=-1), "max_queue"),
    (dict(tenant_quota=0), "tenant_quota"),
    (dict(tenant_quota=-3), "tenant_quota"),
    (dict(mesh_shape=()), "mesh_shape"),
    (dict(mesh_shape=(0,)), "mesh_shape"),
    (dict(mesh_shape=(2, -1)), "mesh_shape"),
    (dict(mesh_shape=(1, 1, 1, 1)), "mesh_shape"),
    (dict(mesh_shape=(2,), tp_axis="tensor"), "tp_axis"),
])
def test_validation_rejects(kwargs, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**kwargs)


def test_defaults_are_valid_and_frozen():
    c = EngineConfig()
    assert c.cache_mode == "paged" and c.tp_shards == 1
    with pytest.raises(dataclasses.FrozenInstanceError):
        c.slots = 8


def test_mesh_shape_list_frozen_to_tuple_and_hashable():
    c = EngineConfig(mesh_shape=[2, 4])
    assert c.mesh_shape == (2, 4)
    assert c.tp_shards == 4 and c.mesh_devices == 8
    hash(c)  # frozen + tuple fields => usable as a cache key


def test_tp_axis_name_irrelevant_without_tp():
    # tp_axis is only constrained when it would actually shard something.
    assert EngineConfig(mesh_shape=(1,), tp_axis="anything").tp_shards == 1


# ---- resolve(): cross-field auto-downgrades --------------------------------

def test_resolve_attn_only_is_identity():
    cfg = registry.get_reduced("qwen2-1.5b")
    c = EngineConfig(spec_decode=True, token_budget=32)
    r = c.resolve(cfg)
    assert r is c and r.downgrades == ()


def test_resolve_recurrent_family_downgrades():
    cfg = registry.get_reduced("rwkv6-1.6b")
    r = EngineConfig(spec_decode=True, token_budget=32).resolve(cfg)
    assert r.decode_mode == "grouped"
    assert r.cache_mode == "dense"
    assert r.spec_decode is False
    assert r.token_budget is None
    assert r.batch_prefill is False
    assert "decode_mode:grouped(recurrent_blocks)" in r.downgrades
    assert "cache_mode:dense(recurrent_blocks)" in r.downgrades


def test_resolve_sliding_window_forces_dense():
    cfg = registry.get_reduced(
        "mixtral-8x22b", capacity_factor=8.0, sliding_window=6
    )
    r = EngineConfig().resolve(cfg)
    assert r.cache_mode == "dense"
    assert "cache_mode:dense(sliding_window)" in r.downgrades


def test_resolve_sampling_switches_spec_off():
    cfg = registry.get_reduced("qwen2-1.5b")
    r = EngineConfig(sample="temperature", spec_decode=True,
                     token_budget=32).resolve(cfg)
    assert r.spec_decode is False and "spec_decode:off(sample)" in r.downgrades
    assert r.token_budget is None
    assert "token_budget:off(needs_verify_window)" in r.downgrades


def test_resolve_grouped_decode_forces_dense():
    cfg = registry.get_reduced("qwen2-1.5b")
    r = EngineConfig(decode_mode="grouped").resolve(cfg)
    assert r.cache_mode == "dense"
    assert "cache_mode:dense(grouped_decode)" in r.downgrades


def test_resolve_idempotent():
    cfg = registry.get_reduced("rwkv6-1.6b")
    r1 = EngineConfig(spec_decode=True).resolve(cfg)
    r2 = r1.resolve(cfg)
    assert r1 == r2


# ---- from_args -------------------------------------------------------------

def test_from_args_maps_fields_and_parses_mesh_strings():
    ns = argparse.Namespace(
        slots=2, max_seq=64, cache_mode="dense", mesh_shape="2x4",
        arch="llama3.2-1b",  # non-config attrs are ignored
    )
    c = EngineConfig.from_args(ns)
    assert c.slots == 2 and c.max_seq == 64 and c.cache_mode == "dense"
    assert c.mesh_shape == (2, 4)
    assert EngineConfig.from_args(
        argparse.Namespace(mesh_shape="2")).mesh_shape == (2,)
    assert EngineConfig.from_args(
        argparse.Namespace(mesh_shape="2,2")).mesh_shape == (2, 2)
    # Missing attrs keep defaults.
    assert c.block_size == EngineConfig().block_size
    assert c.prefix_cache is True and c.tenant_quota is None
    # serve.py's --no-prefix-cache / --tenant-quota route by field name.
    c2 = EngineConfig.from_args(
        argparse.Namespace(prefix_cache=False, tenant_quota=12))
    assert c2.prefix_cache is False and c2.tenant_quota == 12


# ---- the Engine deprecation shim -------------------------------------------

def _model():
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    return cfg, params


def test_shim_and_config_paths_build_identical_configs():
    cfg, params = _model()
    legacy = engine_lib.Engine(
        params, cfg, ENC, slots=2, max_seq=32, cache_mode="paged",
        block_size=8, spec_decode=True,
    )
    explicit = engine_lib.Engine(
        params, cfg, ENC,
        config=EngineConfig(slots=2, max_seq=32, cache_mode="paged",
                            block_size=8, spec_decode=True),
    )
    assert legacy.config == explicit.config
    assert legacy.spec_decode and legacy.cache_mode == "paged"


def test_shim_rejects_config_plus_kwargs():
    cfg, params = _model()
    with pytest.raises(TypeError, match="not both"):
        engine_lib.Engine(params, cfg, ENC, config=EngineConfig(), slots=2)


def test_shim_rejects_unknown_kwarg():
    cfg, params = _model()
    with pytest.raises(TypeError):
        engine_lib.Engine(params, cfg, ENC, slotz=2)


def test_engine_surfaces_resolved_downgrades_in_stats():
    cfg = registry.get_reduced("rwkv6-1.6b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    eng = engine_lib.Engine(params, cfg, ENC, slots=2, max_seq=32,
                            spec_decode=True)
    assert eng.cache_mode == "dense" and eng.decode_mode == "grouped"
    s = eng.stats
    assert any("recurrent_blocks" in d for d in s["config_downgrades"])


def test_engine_token_output_unchanged_by_config_path():
    cfg, params = _model()

    def run(**kw):
        eng = engine_lib.Engine(params, cfg, ENC, **kw)
        for i in range(3):
            eng.submit(engine_lib.Request(
                uid=i, prompt=(np.arange(4 + i) % 7).astype(np.int32),
                max_new_tokens=5,
            ))
        eng.run()
        return {r.uid: list(r.generated) for r in eng.finished}

    legacy = run(slots=2, max_seq=32, block_size=8)
    explicit = run(config=EngineConfig(slots=2, max_seq=32, block_size=8))
    assert legacy == explicit
