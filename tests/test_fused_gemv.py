"""Fused decode GEMV (kernels/fused_gemv.py): parity vs the mmt4d oracle
across ragged M/N/K (padding edges), bf16/f32 and int8, plus the ops.py
routing contract (decode -> fused GEMV, prefill -> fused GEMM slab path)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encoding import Phase
from repro.kernels import fused_gemv, ops, ref


def _rand(shape, dtype, seed=0):
    x = np.random.RandomState(seed).randn(*shape)
    return jnp.asarray(x, dtype)


# Odd M/N/K on purpose: every tile-padding edge (rows, lanes, K) is exercised.
MNK_SWEEP = [
    (1, 256, 128),       # aligned single row (the pure GEMV shape)
    (1, 130, 70),        # ragged N and K
    (3, 100, 300),       # ragged everything, M < sublane group
    (5, 384, 200),       # ragged K only
    (8, 640, 256),       # multi-row decode (8 live slots)
    (17, 129, 257),      # all dims one past a tile boundary
]


@pytest.mark.parametrize("mnk", MNK_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_gemv_matches_mmt4d_oracle(mnk, dtype):
    m, n, k = mnk
    x = _rand((m, k), dtype, seed=m + n)
    w_t = _rand((n, k), dtype, seed=k)
    rhs4 = ops.pack_rhs(w_t)
    # Oracle: the full unfused rewrite (pack -> ref.mmt4d -> unpack).
    n1, k1, n0, k0 = rhs4.shape
    lhs4 = ref.pack(jnp.pad(x, ((0, 0), (0, k1 * k0 - k))), (8, k0))
    want = ref.unpack(ref.mmt4d(lhs4, rhs4), (8 * lhs4.shape[0], n1 * n0))[:m, :n]
    got = ops.encoded_matmul(
        x, rhs4, n=n, phase=Phase.DECODE, backend="fused",
        out_dtype=jnp.float32, interpret=True,
    )
    assert got.shape == (m, n)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol,
        atol=tol * max(1.0, float(jnp.abs(want).max())),
    )


@pytest.mark.parametrize("mnk", [(1, 256, 128), (4, 132, 70), (9, 700, 310)])
def test_fused_gemv_q8_matches_packed_q8(mnk):
    """int8 path: fused epilogue (in-kernel s_a*s_w) == packed q8 kernel path."""
    m, n, k = mnk
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
    rhs4_q, s_w = ops.pack_rhs_q8(w_t)
    want = ops.encoded_matmul_q8(
        x, rhs4_q, s_w, n=n, phase=Phase.DECODE, backend="xla",
        out_dtype=jnp.float32,
    )
    got = ops.encoded_matmul_q8(
        x, rhs4_q, s_w, n=n, phase=Phase.DECODE, backend="fused",
        out_dtype=jnp.float32, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fused_gemv_kernel_direct_bn1_sweep():
    """Direct kernel call: bn1 streaming widths give identical results."""
    m, n, k = 8, 1024, 256
    x = _rand((m, k), jnp.float32)
    rhs4 = ops.pack_rhs(_rand((n, k), jnp.float32, seed=7))
    n1 = rhs4.shape[0]
    outs = [
        fused_gemv.fused_gemv_pallas(x, rhs4, bn1=b, interpret=True)
        for b in (1, 2, 4, 8)
        if n1 % b == 0
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o))


def test_fused_backend_prefill_still_uses_gemm_slab():
    """The fused backend keeps serving prefill GEMMs (row-slab path): big-M
    fused calls agree with the reference too."""
    m, n, k = 200, 136, 264
    x = _rand((m, k), jnp.float32, seed=2)
    w_t = _rand((n, k), jnp.float32, seed=3)
    rhs4 = ops.pack_rhs(w_t)
    want = ref.matmul_reference(x, w_t)
    got = ops.encoded_matmul(
        x, rhs4, n=n, phase=Phase.PREFILL, backend="fused",
        out_dtype=jnp.float32, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)
