"""Chaos-conformance harness + hardened-lifecycle tests (docs/ROBUSTNESS.md).

The conformance contract, replayed from the committed fault schedules in
tests/fault_schedules/: under any schedule drawn from the fault taxonomy
(serving/faults.py), the engine must

  * finish every request with a terminal status (no deadlock — a step budget
    bounds the drive loop),
  * keep survivors TOKEN-IDENTICAL to the fault-free run (greedy decode is
    deterministic; faults may kill requests, never corrupt the others),
  * leak zero pages (allocator audit after every step, pool empty at drain),
  * record every kernel fault in stats["degraded"] with its demotion.

Unit tests below pin the individual lifecycle mechanisms: structured submit
rejection (backpressure), deadlines on an injected clock, cancel mid
speculative-decode, the non-finite logits guard, typed allocator invariant
errors, the decode-step watchdog, and the registry quarantine ladder.
"""

import glob
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.packed import EncodingConfig
from repro.kernels import registry as registry_lib
from repro.models import transformer as T
from repro.runtime import watchdog as watchdog_lib
from repro.serving import engine as engine_lib
from repro.serving import faults as faults_lib
from repro.serving import paged as paged_lib

ENC = EncodingConfig(enabled=True, backend="xla")
SCHEDULE_DIR = os.path.join(os.path.dirname(__file__), "fault_schedules")
SCHEDULES = sorted(glob.glob(os.path.join(SCHEDULE_DIR, "*.json")))

CFG = registry.get_reduced("qwen2-1.5b")
PARAMS = T.model_init(jax.random.PRNGKey(0), CFG, ENC)


@pytest.fixture(autouse=True)
def _clean_quarantine():
    # Kernel quarantine is process-global by design; tests must not bleed
    # demotions into each other (a demoted backend would silently change
    # which kernels every later engine resolves).
    registry_lib.clear_quarantine()
    yield
    registry_lib.clear_quarantine()


def _prompts(seed=0, n=6, repeat=False):
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n):
        base = rng.randint(1, CFG.vocab_size, rng.randint(4, 10)).astype(np.int32)
        out.append(np.tile(base, 3) if repeat else base)
    return out


def _engine(hooks=None, *, prompts, max_new=8, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("max_seq", 64)
    eng = engine_lib.Engine(
        PARAMS, CFG, ENC,
        fault_hooks=hooks,
        clock=(hooks.clock if hooks is not None else None),
        **kw,
    )
    for i, p in enumerate(prompts):
        assert eng.submit(engine_lib.Request(uid=i, prompt=p, max_new_tokens=max_new))
    return eng


def _drive(eng, sched=None, budget=300):
    """Step to completion under a hard step budget (the no-deadlock gate),
    auditing the allocator every step."""
    steps = 0
    while eng.queue or any(r is not None for r in eng.slot_req):
        assert steps < budget, "engine deadlocked under faults"
        eng.step()
        eng.audit()
        steps += 1
    if sched is not None:
        sched.drain(eng)
        eng.audit()
    return steps


def _conformance(schedule_path, *, spec=False, cache_mode="paged", **kw):
    prompts = _prompts(repeat=spec)
    mk = dict(prompts=prompts, cache_mode=cache_mode,
              spec_decode=spec, draft_k=3, **kw)
    gold = {r.uid: list(r.generated)
            for r in _drive_to_finish(_engine(**mk))}
    sched = faults_lib.FaultSchedule.from_json(schedule_path)
    eng = _engine(sched, **mk)
    _drive(eng, sched)
    by_uid = {r.uid: r for r in eng.finished}
    # Every request reached a terminal status.
    assert set(by_uid) == set(range(len(prompts)))
    assert all(r.status in engine_lib.REQUEST_STATUSES and r.done
               for r in eng.finished)
    # Survivors are token-identical to the fault-free run.
    for r in eng.finished:
        if r.status == "ok":
            assert list(r.generated) == gold[r.uid], (
                f"uid {r.uid} diverged under faults"
            )
    # Zero leaked pages once the stream drains.
    if cache_mode == "paged":
        assert eng.alloc.in_use() == 0
        assert eng.alloc.available() == eng.alloc.capacity
    # Kernel faults (if the schedule fired any) are in the audit trail.
    if any(e["kind"] == "kernel_fail" for e in sched.log):
        assert eng.stats["degraded"]
        assert all(registry_lib.quarantine_level(d["key"]) > 0
                   for d in eng.stats["degraded"])
    return eng, sched


def _drive_to_finish(eng):
    _drive(eng)
    return eng.finished


# ---------------------------------------------------------------------------
# Conformance replays of the committed schedules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "path", SCHEDULES, ids=[os.path.basename(p) for p in SCHEDULES]
)
def test_chaos_conformance_paged(path):
    _conformance(path)


def test_chaos_conformance_spec_decode():
    path = os.path.join(SCHEDULE_DIR, "spec_cancel.json")
    eng, _ = _conformance(path, spec=True)
    assert eng.spec_decode  # the spec path actually served this stream


def test_chaos_conformance_dense():
    # pool_spike is paged-only (no pool to seize); everything else must hold
    # identically on the dense cache.
    path = os.path.join(SCHEDULE_DIR, "mixed_paged.json")
    _conformance(path, cache_mode="dense")


def test_chaos_conformance_dense_spec_decode():
    path = os.path.join(SCHEDULE_DIR, "spec_cancel.json")
    eng, _ = _conformance(path, spec=True, cache_mode="dense")
    assert eng.spec_decode


# The same committed schedules replayed through the token-budget mixed
# scheduler (serving/engine.py _mixed_step): the conformance contract —
# terminal statuses, survivor token identity, zero leaked pages, quarantine
# audit trail — must hold when decode and chunked prefill share one
# dispatch.  A kernel_fault during a mixed step quarantines/degrades
# WITHOUT losing the co-scheduled prefill chunks' progress (survivors stay
# token-identical, which they could not if a chunk were dropped or
# double-applied across the retry).
@pytest.mark.parametrize(
    "path", SCHEDULES, ids=[os.path.basename(p) for p in SCHEDULES]
)
def test_chaos_conformance_token_budget(path):
    eng, _ = _conformance(path, token_budget=24)
    assert eng.scheduler is not None
    assert eng.stats["continuous"]["mixed_steps"] > 0


def test_chaos_conformance_token_budget_spec_decode():
    path = os.path.join(SCHEDULE_DIR, "spec_cancel.json")
    eng, _ = _conformance(path, spec=True, token_budget=24)
    assert eng.spec_decode and eng.scheduler is not None


def test_schedule_json_roundtrip(tmp_path):
    sched = faults_lib.FaultSchedule.random(7, steps=12, uids=[0, 1, 2])
    p = sched.to_json(str(tmp_path / "s.json"))
    back = faults_lib.FaultSchedule.from_json(p)
    assert back.seed == sched.seed
    assert [f.to_dict() for f in back.faults] == [f.to_dict() for f in sched.faults]
    # The committed schedules stay regenerable / parseable.
    for path in SCHEDULES:
        with open(path) as f:
            raw = json.load(f)
        assert faults_lib.FaultSchedule.from_dicts(raw["faults"]).faults


def test_fault_kind_validated():
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults_lib.Fault(1, "meteor_strike")


# ---------------------------------------------------------------------------
# Backpressure + admission-time serviceability (satellite a)
# ---------------------------------------------------------------------------


def test_submit_backpressure_queue_full():
    eng = _engine(prompts=[], max_queue=2)
    ok1 = eng.submit(engine_lib.Request(uid=0, prompt=np.arange(1, 5, dtype=np.int32),
                                        max_new_tokens=4))
    ok2 = eng.submit(engine_lib.Request(uid=1, prompt=np.arange(1, 5, dtype=np.int32),
                                        max_new_tokens=4))
    assert ok1 and ok2 and isinstance(ok1, engine_lib.Admitted)
    rej = eng.submit(engine_lib.Request(uid=2, prompt=np.arange(1, 5, dtype=np.int32),
                                        max_new_tokens=4))
    assert not rej and rej.reason == "queue_full"
    assert eng.stats["lifecycle"]["rejected"] == 1
    assert eng.rejected[0].uid == 2 and eng.rejected[0].status == "rejected"
    # The queue drains normally; the rejected request never ran.
    done = {r.uid for r in _drive_to_finish(eng)}
    assert done == {0, 1}


def test_submit_unserviceable_seq_and_pool_boundary():
    eng = _engine(prompts=[], max_seq=32, block_size=4, pool_pages=5)
    too_long = eng.submit(engine_lib.Request(
        uid=0, prompt=np.arange(1, 40, dtype=np.int32), max_new_tokens=1))
    assert not too_long and too_long.reason == "unserviceable_seq"
    # prompt 8 + 9 new = position 16 -> 5 pages > capacity 4: rejected with
    # the worst-case page math in the detail.
    over = eng.submit(engine_lib.Request(
        uid=1, prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=9))
    assert not over and over.reason == "unserviceable_pool"
    # One token fewer needs exactly 4 pages == capacity: admitted and runs.
    fits = eng.submit(engine_lib.Request(
        uid=2, prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=8))
    assert fits
    done = _drive_to_finish(eng)
    assert [r.uid for r in done] == [2] and done[0].status == "ok"
    assert eng.alloc.in_use() == 0


# ---------------------------------------------------------------------------
# Deadlines + cancellation (injected clock)
# ---------------------------------------------------------------------------


def test_deadline_expiry_mid_flight():
    t = [0.0]
    eng = engine_lib.Engine(PARAMS, CFG, ENC, slots=2, max_seq=64,
                            clock=lambda: t[0])
    r0 = engine_lib.Request(uid=0, prompt=np.arange(1, 6, dtype=np.int32),
                            max_new_tokens=50, deadline_ms=1000.0)
    r1 = engine_lib.Request(uid=1, prompt=np.arange(1, 6, dtype=np.int32),
                            max_new_tokens=6)
    assert eng.submit(r0) and eng.submit(r1)
    eng.step()  # both admitted + one token
    assert r0.status == "running"
    t[0] = 2.0  # 2s later: past r0's 1s deadline
    eng.step()
    assert r0.done and r0.status == "expired" and "deadline" in r0.error
    assert len(r0.generated) >= 1  # keeps what it produced
    _drive(eng)
    assert r1.status == "ok" and len(r1.generated) == 6
    assert eng.alloc.in_use() == 0


def test_deadline_expiry_while_queued():
    t = [0.0]
    eng = engine_lib.Engine(PARAMS, CFG, ENC, slots=1, max_seq=64,
                            clock=lambda: t[0])
    reqs = [engine_lib.Request(uid=i, prompt=np.arange(1, 6, dtype=np.int32),
                               max_new_tokens=4, deadline_ms=500.0)
            for i in range(3)]
    for r in reqs:
        assert eng.submit(r)
    eng.step()  # uid 0 admitted; 1 and 2 wait
    t[0] = 1.0
    eng.step()
    statuses = {r.uid: r.status for r in reqs}
    assert statuses[1] == "expired" and statuses[2] == "expired"
    assert reqs[1].generated == [] and reqs[2].generated == []
    _drive(eng)
    statuses = {r.uid: r.status for r in reqs}
    assert statuses[0] == "expired"  # slot 0 also blew its 500ms budget


def test_cancel_while_queued_and_running():
    eng = _engine(prompts=_prompts(n=3), slots=1)
    queued = list(eng.queue)
    eng.step()
    running = next(r for r in queued if r.status == "running")
    waiting = next(r for r in queued if r.status == "queued")
    running.cancel()
    waiting.cancel()
    eng.step()
    assert running.status == "cancelled" and running.done
    assert waiting.status == "cancelled" and waiting.generated == []
    _drive(eng)
    assert eng.alloc.in_use() == 0
    assert eng.stats["lifecycle"]["cancelled"] == 2


# ---------------------------------------------------------------------------
# Cancel mid speculative decode (satellite c)
# ---------------------------------------------------------------------------


def test_cancel_mid_spec_decode_frees_draft_pages():
    """A cancel landing while a verify window is in flight: the cancelled
    request emits nothing from that window (its pages — draft positions
    included — return to the pool), and the co-batched slot's stream is
    token-identical to the fault-free run."""
    prompts = _prompts(seed=5, n=2, repeat=True)
    gold = {r.uid: list(r.generated) for r in _drive_to_finish(
        _engine(prompts=prompts, slots=2, spec_decode=True, draft_k=3,
                max_new=10))}

    sched = faults_lib.FaultSchedule(
        [faults_lib.Fault(3, "cancel", uid=0, where="mid")], seed=0)
    eng = _engine(sched, prompts=prompts, slots=2, spec_decode=True,
                  draft_k=3, max_new=10)
    assert eng.spec_decode
    _drive(eng, sched)
    by_uid = {r.uid: r for r in eng.finished}
    assert by_uid[0].status == "cancelled"
    # The mid cancel fired during a dispatch (the schedule logs which).
    mid = [e for e in sched.log if e["kind"] == "cancel"]
    assert mid and mid[0]["where"] == "mid"
    # Cancelled before the window's tokens committed: strictly fewer tokens
    # than the fault-free run of the same request.
    assert len(by_uid[0].generated) < len(gold[0])
    # Co-batched request: byte-for-byte the fault-free stream.
    assert by_uid[1].status == "ok"
    assert list(by_uid[1].generated) == gold[1]
    # Every page (committed AND draft-only) is back in the pool.
    assert eng.alloc.in_use() == 0


def test_spec_survivor_page_truncation_under_cancel():
    """While one slot dies mid-window, the survivor's trailing draft-only
    pages still roll back to exactly its committed need (the
    _truncate_slot_pages path), verified by the per-step audit in _drive."""
    prompts = _prompts(seed=9, n=2, repeat=True)
    sched = faults_lib.FaultSchedule(
        [faults_lib.Fault(2, "cancel", uid=1, where="mid")], seed=0)
    eng = _engine(sched, prompts=prompts, slots=2, spec_decode=True,
                  draft_k=4, max_new=12, block_size=4, pool_pages=32)
    while any(r is not None for r in eng.slot_req) or eng.queue:
        eng.step()
        eng.audit()
        for s in range(eng.slots):
            if eng.slot_req[s] is not None:
                # Never MORE pages than the committed history + next write
                # need: trailing draft-only pages must have rolled back.
                # (Fewer is fine — growth pages allocate lazily next step.)
                need = (int(eng.slot_pos[s]) - 1) // eng.block_size + 1
                assert len(eng.slot_pages[s]) <= need, (
                    "draft-only pages survived the rollback"
                )
    sched.drain(eng)
    assert eng.alloc.in_use() == 0


# ---------------------------------------------------------------------------
# Non-finite logits guard
# ---------------------------------------------------------------------------


def test_guard_quarantines_only_offending_slot():
    prompts = _prompts(seed=2, n=2)
    gold = {r.uid: list(r.generated)
            for r in _drive_to_finish(_engine(prompts=prompts, slots=2))}
    sched = faults_lib.FaultSchedule(
        [faults_lib.Fault(2, "nonfinite_logits", uid=0)], seed=0)
    eng = _engine(sched, prompts=prompts, slots=2)
    _drive(eng, sched)
    by_uid = {r.uid: r for r in eng.finished}
    assert by_uid[0].status == "error" and "non-finite" in by_uid[0].error
    assert by_uid[1].status == "ok" and list(by_uid[1].generated) == gold[1]
    assert eng.stats["lifecycle"]["guard_trips"] == 1


def test_guard_flag_off_skips_check():
    sched = faults_lib.FaultSchedule(
        [faults_lib.Fault(2, "nonfinite_logits", uid=0)], seed=0)
    eng = _engine(sched, prompts=_prompts(n=1), slots=1, logits_guard=False)
    _drive(eng, sched)
    # With the guard off the corruption goes unchecked (nothing trips, the
    # request ends "ok") — the flag exists so benchmarks can measure the
    # guard's own per-step overhead against an unguarded run.
    assert eng.stats["lifecycle"]["guard_trips"] == 0
    assert eng.finished[0].status == "ok"


def test_poisoned_kv_trips_guard_next_step():
    sched = faults_lib.FaultSchedule(
        [faults_lib.Fault(3, "nonfinite_kv", uid=0)], seed=0)
    eng = _engine(sched, prompts=_prompts(n=1), slots=1, max_new=10)
    _drive(eng, sched)
    assert eng.finished[0].status == "error"
    assert eng.stats["lifecycle"]["guard_trips"] >= 1
    assert eng.alloc.in_use() == 0


def test_poisoned_kv_quantized_pages_isolated_to_slot():
    """nonfinite_kv under the kv8 layout: integer data pages cannot hold a
    NaN, so the injection saturates them AND NaNs the float32 scale pages —
    dequantize still goes non-finite, the guard still trips, and it
    quarantines ONLY the offending slot.  Co-batched survivors must stay
    token-identical to the fault-free kv8 run (their pages are private;
    the poison cannot leak through the shared pool)."""
    prompts = _prompts(n=3)
    gold = {r.uid: list(r.generated)
            for r in _drive_to_finish(_engine(prompts=prompts, kv_quant="kv8"))}
    sched = faults_lib.FaultSchedule(
        [faults_lib.Fault(3, "nonfinite_kv", uid=0)], seed=0)
    eng = _engine(sched, prompts=prompts, kv_quant="kv8")
    _drive(eng, sched)
    assert eng.stats["kv_quant"] == "kv8"
    by_uid = {r.uid: r for r in eng.finished}
    assert by_uid[0].status == "error"
    assert eng.stats["lifecycle"]["guard_trips"] >= 1
    for uid in (1, 2):
        assert by_uid[uid].status == "ok", by_uid[uid].error
        assert list(by_uid[uid].generated) == gold[uid], (
            f"survivor uid {uid} diverged under kv8 poison"
        )
    assert eng.alloc.in_use() == 0
    # Scale state tracks the allocated set in lockstep: after the drain the
    # only allocated pages are the refcount-0 blocks parked in the prefix
    # tree, and exactly those keep their scales (for revival on a hit).
    assert eng.alloc.scale_live == eng.alloc.cached


def test_chaos_conformance_kv8():
    """The full conformance contract (terminal statuses, survivor token
    identity, zero leaked pages + zero leaked scale state, quarantine audit
    trail) holds with the quantized layout, replaying the committed
    kv-quant schedule."""
    path = os.path.join(SCHEDULE_DIR, "kv_quant_mix.json")
    eng, _ = _conformance(path, kv_quant="kv8")
    assert eng.stats["kv_quant"] == "kv8"
    # Lockstep invariant: scales survive exactly on tree-cached pages.
    assert eng.alloc.scale_live == eng.alloc.cached


# ---------------------------------------------------------------------------
# Typed allocator invariants (satellite b)
# ---------------------------------------------------------------------------


def test_allocator_double_free_is_typed():
    alloc = paged_lib.BlockAllocator(8, 4)
    p = alloc.alloc(owner=2)
    alloc.free_page(p)
    with pytest.raises(paged_lib.AllocatorInvariantError) as ei:
        alloc.free_page(p, owner=2)
    assert ei.value.page == p and ei.value.owner == 2
    assert f"page {p}" in str(ei.value) and "slot 2" in str(ei.value)
    assert isinstance(ei.value, AssertionError)  # old contracts still hold


def test_allocator_share_unreferenced_is_typed():
    alloc = paged_lib.BlockAllocator(8, 4)
    p = alloc.alloc()
    alloc.free_page(p)
    with pytest.raises(paged_lib.AllocatorInvariantError):
        alloc.share(p)


def test_audit_catches_stale_prefix_tree_entry():
    """A freed page left reachable from the radix tree is the cross-request
    corruption precursor (a recycled page would serve another tenant's KV
    as a cache hit): audit must name it."""
    alloc = paged_lib.BlockAllocator(8, 4)
    prompt = np.arange(1, 10, dtype=np.int32)  # 9 tokens -> 2 shareable blocks
    nblocks, shared = alloc.plan_prompt(prompt)
    plan = alloc.commit_prompt(prompt, nblocks, shared)
    alloc.mark_written(plan.pages)
    alloc.free_pages(plan.pages)  # shareable blocks park in the tree, rc 0
    stale = plan.pages[0]
    assert stale in alloc.cached
    # Simulate the bug: page recycled onto the free list while its tree
    # node survives (reaping skipped on the free path).
    alloc.cached.discard(stale)
    alloc.free.append(stale)
    with pytest.raises(paged_lib.AllocatorInvariantError,
                       match="prefix tree references a freed page"):
        alloc.audit([])


def test_audit_leak_names_owner():
    alloc = paged_lib.BlockAllocator(8, 4)
    p = alloc.alloc(owner=1)
    with pytest.raises(paged_lib.AllocatorInvariantError) as ei:
        alloc.audit([])  # page allocated but referenced by no table: a leak
    assert ei.value.page == p and ei.value.owner == 1


# ---------------------------------------------------------------------------
# Decode-step watchdog
# ---------------------------------------------------------------------------


def test_watchdog_stall_detection_and_percentiles():
    t = [0.0]
    wd = watchdog_lib.DecodeStepWatchdog(clock=lambda: t[0])
    for _ in range(8):  # warmup + steady 10ms steps
        wd.step_start()
        t[0] += 0.010
        assert wd.step_end() is False
    wd.step_start()
    t[0] += 0.200  # 20x the EWMA: a stall
    assert wd.step_end() is True
    s = wd.summary()
    assert s["stalls"] == 1 and s["stalled"]
    assert s["p50_ms"] == pytest.approx(10.0, rel=0.2)
    assert s["p99_ms"] > s["p50_ms"]
    # The stalled sample was clamped: the EWMA didn't absorb the spike.
    assert s["ewma_ms"] < 50.0
    # Recovery: the next normal step is not a stall.
    wd.step_start()
    t[0] += 0.010
    assert wd.step_end() is False


def test_watchdog_wired_into_engine_stats():
    eng = _engine(prompts=_prompts(n=2), slots=2)
    _drive(eng)
    wd = eng.stats["watchdog"]
    assert wd["steps"] == eng.stats["steps"] > 0
    assert wd["p50_ms"] >= 0.0 and wd["ewma_ms"] > 0.0


def test_watchdog_sees_injected_clock_skew():
    sched = faults_lib.FaultSchedule(
        [faults_lib.Fault(8, "clock_skew", skew_s=30.0)], seed=0)
    eng = _engine(sched, prompts=_prompts(n=2), slots=2, max_new=12)
    _drive(eng, sched)
    assert eng.stats["watchdog"]["stalls"] >= 1  # the skewed step flagged


# ---------------------------------------------------------------------------
# Kernel quarantine (registry demotion ladder)
# ---------------------------------------------------------------------------


def test_registry_demotes_down_ladder():
    key = registry_lib.dispatch_key(
        "none", engine_lib.Phase.DECODE, 4, "tpu-v5e")
    first = registry_lib.resolve_key(key, requested="xla")
    rec = registry_lib.demote(key, failing=first.backend, requested="xla")
    assert rec["from"] == first.backend and rec["to"] != first.backend
    demoted = registry_lib.resolve_key(key, requested="xla")
    assert demoted.backend == rec["to"]
    assert demoted.source.startswith("quarantined:")
    assert registry_lib.quarantine_level(key) >= 1
    snap = registry_lib.quarantine_snapshot()
    assert key in snap and snap[key]["to"] == demoted.backend


def test_engine_quarantine_survives_for_process_and_records():
    sched = faults_lib.FaultSchedule(
        [faults_lib.Fault(2, "kernel_fail", key="attn|decode|*")], seed=0)
    eng = _engine(sched, prompts=_prompts(n=2), slots=2)
    _drive(eng, sched)
    deg = eng.stats["degraded"]
    assert len(deg) == 1
    d = deg[0]
    assert d["key"].startswith("attn|decode|")
    assert d["from"] != d["to"] and d["reason"]
    assert registry_lib.quarantine_level(d["key"]) == d["level"] == 1
    assert eng.stats["lifecycle"]["kernel_faults"] == 1
    # A second engine in the same process resolves the demoted backend too.
    eng2 = _engine(prompts=_prompts(n=1), slots=1)
    _drive(eng2)
    assert eng2.finished[0].status == "ok"
    assert registry_lib.quarantine_level(d["key"]) == 1


def test_dispatch_exhausting_ladder_raises():
    # Six faults armed at the SAME step: each in-step retry after a demotion
    # consumes (and fires) another one, so the dispatch keeps failing past
    # the bottom of the ladder — the engine must surface the failure rather
    # than loop.
    faultlist = [faults_lib.Fault(1, "kernel_fail", key="*") for _ in range(6)]
    sched = faults_lib.FaultSchedule(faultlist, seed=0)
    eng = _engine(sched, prompts=_prompts(n=1), slots=1)
    with pytest.raises(faults_lib.KernelFaultError):
        for _ in range(10):
            eng.step()
