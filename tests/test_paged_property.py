"""Property-based round-trip tests (hypothesis; skipped when absent, run in
CI): block-table gathers reproduce dense cache slices for arbitrary valid
tables, the encoding round-trip (pack/unpack + encoded_matmul) holds over
ragged shapes, the paged attention KERNEL path (in-kernel block-table
gather) stays bit-consistent with the dense kernel on the gathered view,
and the radix-tree prefix cache (serving/paged.py) survives randomized
admit/finish/evict/COW storms with exact audits, LCP lookups matching a
brute-force oracle, and kv8 scale pages moving in lockstep."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import encoding as encoding_lib  # noqa: E402
from repro.core.encoding import Phase  # noqa: E402
from repro.kernels import attn as attn_lib  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.models import layers as L  # noqa: E402
from repro.serving import paged as paged_lib  # noqa: E402

_SETTINGS = dict(max_examples=25, deadline=None)


@settings(**_SETTINGS)
@given(
    b=st.integers(1, 4),
    nb=st.integers(1, 5),
    bs=st.sampled_from([1, 2, 4, 8]),
    kv=st.integers(1, 2),
    hd=st.integers(1, 8),
    share=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_table_gather_equals_dense_slice(b, nb, bs, kv, hd, share, seed):
    """For ANY valid block table — including tables where slots share pages —
    paged_gather(pool, table) is exactly the dense (B, NB*bs, ...) cache the
    tables describe."""
    rng = np.random.RandomState(seed)
    pool = rng.randn(1 + b * nb, bs, kv, hd).astype(np.float32)
    if share and b > 1:
        # Slots 0 and 1 share their leading block's page (prefix reuse).
        table = rng.randint(1, pool.shape[0], size=(b, nb)).astype(np.int32)
        table[1, 0] = table[0, 0]
    else:
        table = (1 + rng.permutation(b * nb)).reshape(b, nb).astype(np.int32)
    dense = pool[table].reshape(b, nb * bs, kv, hd)  # definitionally dense
    got = L.paged_gather(jnp.asarray(pool), jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(got), dense)
    # Slot-sliced view == dense row slice, any slot, any position range.
    s = int(rng.randint(b))
    np.testing.assert_array_equal(np.asarray(got[s]), dense[s])


@settings(**_SETTINGS)
@given(
    r=st.integers(1, 40),
    c=st.integers(1, 40),
    t0=st.sampled_from([1, 2, 4, 8]),
    t1=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip_ragged(r, c, t0, t1, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(r, c), jnp.float32)
    back = ref.unpack(ref.pack(x, (t0, t1)), (r, c))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@settings(**_SETTINGS)
@given(
    m=st.integers(1, 20),
    n=st.integers(1, 80),
    k=st.integers(1, 80),
    phase=st.sampled_from([Phase.PREFILL, Phase.DECODE]),
    seed=st.integers(0, 2**31 - 1),
)
def test_encoded_matmul_parity_ragged(m, n, k, phase, seed):
    """pack -> mmt4d -> unpack == plain contraction for arbitrary ragged
    (M, N, K) — the encoding is a pure layout change, never a value change."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(0.1 * rng.randn(m, k), jnp.float32)
    w_t = jnp.asarray(0.1 * rng.randn(n, k), jnp.float32)
    want = np.asarray(ref.matmul_reference(x, w_t))
    got = np.asarray(ops.encoded_matmul(
        x, ops.pack_rhs(w_t), n=n, phase=phase, backend="xla",
        out_dtype=jnp.float32,
    ))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    nb=st.integers(1, 4),
    bs=st.sampled_from([2, 4, 8]),
    kv=st.integers(1, 2),
    g=st.sampled_from([1, 2, 4]),
    lq=st.integers(1, 3),
    share=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_kernel_bit_consistent_with_dense_kernel(
    b, nb, bs, kv, g, lq, share, seed
):
    """For ANY valid block table (shared prefix pages included), per-row
    positions and verify-window widths, the paged-decode kernel's in-kernel
    gather is BITWISE the dense-decode kernel run on the materialized
    `paged_gather` view at matched streaming granularity — and both stay
    within fp tolerance of the jnp reference."""
    if lq > nb * bs:
        lq = 1
    rng = np.random.RandomState(seed)
    d, h = 8, kv * g
    pool_k = jnp.asarray(rng.randn(1 + b * nb, bs, kv, d), np.float32)
    pool_v = jnp.asarray(rng.randn(1 + b * nb, bs, kv, d), np.float32)
    table = (1 + rng.permutation(b * nb).reshape(b, nb)).astype(np.int32)
    if share and b > 1:
        table[1, 0] = table[0, 0]
    table = jnp.asarray(table)
    q = jnp.asarray(rng.randn(b, lq, h, d), np.float32)
    pos = jnp.asarray(rng.randint(0, nb * bs - lq + 1, b), jnp.int32)

    paged = attn_lib.paged_decode_attention(
        q, pool_k, pool_v, table, pos, interpret=True
    )
    dense = attn_lib.dense_decode_attention(
        q, L.paged_gather(pool_k, table), L.paged_gather(pool_v, table),
        pos, window=0, kv_chunk=bs, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))
    want = L.attention_decode(
        q, L.paged_gather(pool_k, table), L.paged_gather(pool_v, table),
        pos=pos, window=0,
    )
    np.testing.assert_allclose(
        np.asarray(paged), np.asarray(want), rtol=2e-5, atol=2e-5
    )


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    nb=st.integers(2, 5),
    bs=st.sampled_from([2, 4]),
    nb_bound=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_gather_bound_is_prefix_of_full_gather(b, nb, bs, nb_bound, seed):
    """paged_gather(nb_blocks=) == the leading slice of the full gather, for
    any bound (larger-than-table bounds are clamped)."""
    rng = np.random.RandomState(seed)
    pool = jnp.asarray(rng.randn(1 + b * nb, bs, 1, 4), np.float32)
    table = jnp.asarray(
        (1 + rng.permutation(b * nb).reshape(b, nb)).astype(np.int32)
    )
    full = L.paged_gather(pool, table)
    got = L.paged_gather(pool, table, nb_blocks=nb_bound)
    eff = min(nb_bound, nb)
    assert got.shape[1] == eff * bs
    np.testing.assert_array_equal(np.asarray(got), np.asarray(full[:, : eff * bs]))


# ---- KVLayout codec (core/encoding.py kv8/kv4) -----------------------------


@settings(**_SETTINGS)
@given(
    name=st.sampled_from(["kv8", "kv4"]),
    bs=st.sampled_from([2, 4, 8]),
    kv=st.integers(1, 3),
    hd=st.sampled_from([2, 4, 8, 16]),
    scale_pow=st.integers(-6, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_kv_layout_roundtrip_error_bound(name, bs, kv, hd, scale_pow, seed):
    """pack -> unpack stays within half a quantization step of the input,
    per (token, head) row, at ANY magnitude: the per-row absmax scale makes
    the codec exact up to |x|_max / (2 * qmax) + rounding slack."""
    layout = encoding_lib.kv_layout(name)
    rng = np.random.RandomState(seed)
    x = (2.0 ** scale_pow) * rng.randn(bs, kv, hd).astype(np.float32)
    q, scale = layout.quantize(jnp.asarray(x))
    assert q.dtype == layout.storage_dtype
    assert q.shape[-1] == layout.storage_head_dim(hd)
    assert scale.shape == (bs, kv, 1)
    deq = np.asarray(layout.dequantize(q, scale))
    assert deq.shape == x.shape
    amax = np.abs(x).max(axis=-1, keepdims=True)
    # Half a step per row, plus float slack for the scale multiply.
    bound = amax / (2.0 * layout.qmax) + 1e-6 * np.maximum(amax, 1.0)
    assert np.all(np.abs(deq - x) <= bound + 1e-12)


@settings(**_SETTINGS)
@given(
    name=st.sampled_from(["kv8", "kv4"]),
    bs=st.sampled_from([4, 8]),
    tail=st.integers(1, 7),
    kv=st.integers(1, 2),
    hd=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kv_layout_ragged_tail_rows_independent(name, bs, tail, kv, hd, seed):
    """A ragged last page (only `tail` of `bs` token rows written) decodes
    its written rows identically to a full page holding the same values:
    scales are per (token, head) row, so garbage/zero tail rows can never
    perturb real rows."""
    tail = min(tail, bs)
    layout = encoding_lib.kv_layout(name)
    rng = np.random.RandomState(seed)
    full = rng.randn(bs, kv, hd).astype(np.float32)
    ragged = full.copy()
    ragged[tail:] = 0.0  # unwritten tail rows (zeros, as cache_init leaves)
    qf, sf = layout.quantize(jnp.asarray(full))
    qr, sr = layout.quantize(jnp.asarray(ragged))
    np.testing.assert_array_equal(np.asarray(qf)[:tail], np.asarray(qr)[:tail])
    np.testing.assert_array_equal(np.asarray(sf)[:tail], np.asarray(sr)[:tail])
    deq_f = np.asarray(layout.dequantize(qf, sf))
    deq_r = np.asarray(layout.dequantize(qr, sr))
    np.testing.assert_array_equal(deq_f[:tail], deq_r[:tail])
    np.testing.assert_array_equal(deq_r[tail:], np.zeros_like(deq_r[tail:]))


@settings(**_SETTINGS)
@given(
    name=st.sampled_from(["kv8", "kv4"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kv_layout_requantize_idempotent(name, seed):
    """Re-quantizing a dequantized page is a fixed point: quantize(deq(q, s))
    returns the same codes bit-for-bit (the absmax row survives the round
    trip, so the recovered scale matches and every code re-rounds to
    itself)."""
    layout = encoding_lib.kv_layout(name)
    rng = np.random.RandomState(seed)
    x = rng.randn(4, 2, 8).astype(np.float32)
    q, s = layout.quantize(jnp.asarray(x))
    deq = layout.dequantize(q, s)
    q2, s2 = layout.quantize(deq)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    nb=st.integers(1, 4),
    bs=st.sampled_from([2, 4, 8]),
    kv=st.integers(1, 2),
    g=st.sampled_from([1, 2]),
    lq=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_kernel_bit_consistent_with_dense_kernel_kv8(
    b, nb, bs, kv, g, lq, seed
):
    """The kv8 paged-decode kernel (scale pages ride the block table, tiles
    dequantized in VMEM) is BITWISE the kv8 dense-decode kernel on the
    gathered quantized view at matched streaming granularity — the same
    contract the bf16 kernels pin above, extended to the quantized layout."""
    if lq > nb * bs:
        lq = 1
    layout = encoding_lib.kv_layout("kv8")
    rng = np.random.RandomState(seed)
    d, h = 8, kv * g
    k_raw = jnp.asarray(rng.randn(1 + b * nb, bs, kv, d), np.float32)
    v_raw = jnp.asarray(rng.randn(1 + b * nb, bs, kv, d), np.float32)
    pool_k, ks = layout.quantize(k_raw)
    pool_v, vs = layout.quantize(v_raw)
    table = jnp.asarray(
        (1 + rng.permutation(b * nb).reshape(b, nb)).astype(np.int32)
    )
    q = jnp.asarray(rng.randn(b, lq, h, d), np.float32)
    pos = jnp.asarray(rng.randint(0, nb * bs - lq + 1, b), jnp.int32)

    paged = attn_lib.paged_decode_attention(
        q, pool_k, pool_v, table, pos,
        k_scale=ks, v_scale=vs, kv_quant="kv8", interpret=True,
    )
    dense = attn_lib.dense_decode_attention(
        q, L.paged_gather(pool_k, table), L.paged_gather(pool_v, table),
        pos, window=0, kv_chunk=bs,
        k_scale=L.paged_gather(ks, table), v_scale=L.paged_gather(vs, table),
        kv_quant="kv8", interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))
    # Both agree with the jnp reference run on the dequantized view.
    want = L.attention_decode(
        q,
        layout.dequantize(L.paged_gather(pool_k, table),
                          L.paged_gather(ks, table)),
        layout.dequantize(L.paged_gather(pool_v, table),
                          L.paged_gather(vs, table)),
        pos=pos, window=0,
    )
    np.testing.assert_allclose(
        np.asarray(paged), np.asarray(want), rtol=2e-5, atol=2e-5
    )


# ---- Radix-tree prefix cache (serving/paged.py) ----------------------------


def _blocks(prompt, bs):
    return [tuple(int(t) for t in prompt[j * bs:(j + 1) * bs])
            for j in range(max(0, (len(prompt) - 1) // bs))]


@settings(**_SETTINGS)
@given(
    bs=st.sampled_from([2, 4]),
    nprompts=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_radix_lcp_matches_bruteforce_oracle(bs, nprompts, seed):
    """plan_prompt's shared run is EXACTLY the longest-common-prefix of full
    immutable blocks against everything ever committed — computed here by a
    brute-force prefix-set oracle, with page identity pinned per chain.  A
    tiny token alphabet forces dense prefix collisions; the pool is sized so
    eviction never fires and the oracle stays monotone."""
    rng = np.random.RandomState(seed)
    alloc = paged_lib.BlockAllocator(1 + 48, bs)
    oracle_page: dict[tuple, int] = {}  # block-chain -> page
    live = []
    for _ in range(nprompts):
        prompt = rng.randint(1, 4, size=rng.randint(1, 4 * bs + 4)).astype(
            np.int32
        )
        chain = _blocks(prompt, bs)
        nblocks, shared = alloc.plan_prompt(prompt)
        # Oracle LCP: longest leading run of chains already registered.
        lcp = 0
        while lcp < len(chain) and tuple(chain[: lcp + 1]) in oracle_page:
            lcp += 1
        assert sorted(shared) == list(range(lcp)), (
            f"shared run {sorted(shared)} != oracle LCP {lcp}"
        )
        for j in range(lcp):
            assert shared[j] == oracle_page[tuple(chain[: j + 1])], (
                f"block {j}: page {shared[j]} != oracle"
            )
        plan = alloc.commit_prompt(prompt, nblocks, shared)
        assert plan is not None
        alloc.mark_written(plan.pages)
        for j in range(len(chain)):
            oracle_page.setdefault(tuple(chain[: j + 1]), plan.pages[j])
        live.append(plan)
        # Randomly finish some earlier requests: their immutable blocks park
        # in the tree (never leave the oracle — the pool never evicts here).
        while len(live) > 1 and rng.rand() < 0.5:
            done = live.pop(int(rng.randint(len(live))))
            alloc.free_pages(done.pages)
        alloc.audit([p.pages for p in live])


@settings(**_SETTINGS)
@given(
    bs=st.sampled_from([2, 4]),
    pool=st.integers(8, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_eviction_never_touches_live_chains(bs, pool, seed):
    """Draining the pool with raw allocs evicts ONLY cold cached leaves:
    pages of the one live plan are never handed out again, and once just the
    live chain remains, alloc() returns None instead of preempting it."""
    rng = np.random.RandomState(seed)
    alloc = paged_lib.BlockAllocator(1 + pool, bs)
    # Warm the tree with a few finished (cached) chains...
    for _ in range(3):
        prompt = rng.randint(1, 4, size=rng.randint(1, 3 * bs)).astype(np.int32)
        nblocks, shared = alloc.plan_prompt(prompt)
        plan = alloc.commit_prompt(prompt, nblocks, shared)
        if plan is None:
            continue
        alloc.mark_written(plan.pages)
        alloc.free_pages(plan.pages)
    # ...and keep ONE plan live.
    prompt = rng.randint(1, 4, size=2 * bs + 1).astype(np.int32)
    nblocks, shared = alloc.plan_prompt(prompt)
    plan = alloc.commit_prompt(prompt, nblocks, shared)
    if plan is None:
        return  # tiny pool + warm chains left no room: nothing to protect
    alloc.mark_written(plan.pages)
    livepages = set(plan.pages)
    held = []
    while True:
        page = alloc.alloc(owner=7)
        if page is None:
            break
        assert page not in livepages, "eviction recycled a live page"
        held.append(page)
        alloc.audit([plan.pages, held])
    # Pool exhausted: everything except the live chain was reclaimable.
    assert len(held) + len(plan.pages) == alloc.capacity
    for p in livepages:
        assert alloc.refcount[p] > 0
    alloc.free_pages(held, owner=7)
    alloc.free_pages(plan.pages)
    alloc.audit([])


@settings(max_examples=20, deadline=None)
@given(
    bs=st.sampled_from([2, 4]),
    pool=st.integers(6, 14),
    kv_quant=st.sampled_from(["bf16", "kv8"]),
    quota=st.sampled_from([None, 4]),
    nops=st.integers(10, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_audit_exact_under_admit_finish_evict_cow_storm(
    bs, pool, kv_quant, quota, nops, seed
):
    """Randomized storms of admit (partial writes included), finish, COW
    shares, and raw-alloc pool pressure (forcing evictions) keep audit()
    exact after EVERY op — and under kv8 the scale pages track the allocated
    set (referenced + cached) in lockstep throughout."""
    rng = np.random.RandomState(seed)
    alloc = paged_lib.BlockAllocator(
        1 + pool, bs, kv_quant=kv_quant, tenant_quota=quota
    )
    live: list[tuple[list, str]] = []   # (pages, tenant) per virtual slot
    held: list[int] = []                # raw-alloc'd pressure pages
    for _ in range(nops):
        op = rng.choice(["admit", "finish", "cow", "pressure", "release"])
        tenant = str(rng.choice(["a", "b"]))
        if op == "admit":
            prompt = rng.randint(1, 4, size=rng.randint(1, 3 * bs + 2)).astype(
                np.int32
            )
            nblocks, shared = alloc.plan_prompt(prompt)
            plan = alloc.commit_prompt(prompt, nblocks, shared, tenant=tenant)
            if plan is not None:
                # Partial write: only a leading run lands (mirrors chunked
                # prefill); unwritten registered blocks must unregister
                # their whole subtree when freed early.
                k = int(rng.randint(0, len(plan.pages) + 1))
                alloc.mark_written(plan.pages[:k])
                live.append((plan.pages, tenant))
        elif op == "finish" and live:
            pages, t = live.pop(int(rng.randint(len(live))))
            alloc.free_pages(pages, tenant=t)
        elif op == "cow" and live:
            # Sharing only ever flows through the tree (plan/commit): pick a
            # REGISTERED live page, as a second reader of its prefix would.
            pages, t = live[int(rng.randint(len(live)))]
            p = pages[int(rng.randint(len(pages)))]
            if alloc.refcount[p] > 0 and alloc.is_registered(p):
                alloc.share(p, tenant=tenant)
                live.append(([p], tenant))
        elif op == "pressure":
            page = alloc.alloc(owner=9, tenant=tenant)
            if page is not None:
                held.append(page)
        elif op == "release" and held:
            alloc.free_page(held.pop(), owner=9)
        tables = [pages for pages, _ in live] + ([held] if held else [])
        alloc.audit(tables)
        if kv_quant != "bf16":
            referenced = {
                p for p in range(1, alloc.num_pages) if alloc.refcount[p] > 0
            }
            assert alloc.scale_live == referenced | alloc.cached, (
                "kv8 scale pages out of lockstep"
            )
    for pages, t in live:
        alloc.free_pages(pages, tenant=t)
    alloc.free_pages(held, owner=9)
    alloc.audit([])
    assert alloc.in_use() == 0
    assert alloc.stats["allocs"] == alloc.stats["frees"]


def test_pool_spike_chaos_against_warm_cache():
    """Replay pool_spike seizures (serving/faults.py) against a WARM prefix
    cache: a second wave of shared-prefix requests admits off cached chains
    while the fault schedule drains the free list, forcing evictions to race
    revivals.  Survivors stay token-identical to the fault-free warm run,
    the audit stays exact every step, and the drain leaks nothing."""
    import jax
    from repro.configs import registry
    from repro.core.packed import EncodingConfig
    from repro.models import transformer as T
    from repro.serving import engine as engine_lib
    from repro.serving import faults as faults_lib

    cfg = registry.get_reduced("qwen2-1.5b")
    enc = EncodingConfig(enabled=True, backend="xla")
    params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
    rng = np.random.RandomState(7)
    base = rng.randint(1, cfg.vocab_size, 16).astype(np.int32)  # 2 blocks @ 8
    prompts = [
        np.concatenate([base, rng.randint(1, cfg.vocab_size,
                                          4 + i).astype(np.int32)])
        for i in range(4)
    ]

    def run(sched):
        eng = engine_lib.Engine(
            params, cfg, enc, fault_hooks=sched,
            slots=2, max_seq=64, block_size=8, pool_pages=14,
        )
        for wave in range(2):
            for i, p in enumerate(prompts):
                assert eng.submit(engine_lib.Request(
                    uid=wave * 10 + i, prompt=p, max_new_tokens=6,
                    tenant=f"t{i % 2}",
                ))
            steps = 0
            while eng.queue or any(r is not None for r in eng.slot_req):
                assert steps < 300, "engine deadlocked under pool_spike"
                eng.step()
                eng.audit()
                steps += 1
        if sched is not None:
            sched.drain(eng)
            eng.audit()
        return eng

    gold = run(None)
    want = {r.uid: list(r.generated) for r in gold.finished}
    assert gold.alloc.stats["hit_blocks"] > 0, "second wave never hit"

    sched = faults_lib.FaultSchedule(
        [faults_lib.Fault(s, "pool_spike", pages=3, hold=2)
         for s in (2, 9, 16, 23, 30)],
        seed=7,
    )
    eng = run(sched)
    assert {r.uid for r in eng.finished} == set(want)
    for r in eng.finished:
        assert r.status == "ok", (r.uid, r.status, r.error)
        assert list(r.generated) == want[r.uid], (
            f"uid {r.uid} diverged under pool_spike on a warm cache"
        )
    assert eng.alloc.in_use() == 0
    assert eng.alloc.stats["allocs"] == eng.alloc.stats["frees"]
