"""Sharding rules: sanitization, spec assignment, and a real multi-device
SPMD integration run (8 fake CPU devices in a subprocess)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.parallel import sharding

# jax 0.4.37 (the pinned CI minimum) predates jax.sharding.AxisType /
# make_mesh(axis_types=...): these tests exercise the newer-jax SPMD API
# and skip on the pinned leg (they run on the latest-jax CI leg).
requires_axis_types = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType not available on this jax version",
)


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@requires_axis_types
def test_sanitize_drops_nondividing_axes():
    mesh = _mesh11()
    # 1x1 mesh divides everything; use spec structure checks instead.
    s = sharding.sanitize(P("data", "model"), (4, 4), mesh)
    assert s == P("data", "model")


@requires_axis_types
def test_param_specs_classification():
    mesh = _mesh11()
    cfg = registry.get_reduced("qwen2-1.5b")
    enc = EncodingConfig(enabled=True, backend="xla")
    params = jax.eval_shape(lambda k: T.model_init(k, cfg, enc), jax.random.PRNGKey(0))
    sh = sharding.params_shardings(params, mesh)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    by_name = {}
    for path, s in flat:
        by_name[jax.tree_util.keystr(path)] = s
    # Column-parallel: wq N1 on model; row-parallel: wo K1 on model.
    wq = next(v.spec for k, v in by_name.items() if "wq" in k and "w_packed" in k)
    wo = next(v.spec for k, v in by_name.items() if "'wo'" in k and "w_packed" in k)
    assert "model" in str(wq[1]) and "model" in str(wo[2]), (wq, wo)
    # Norm scales replicated.
    norm = next(v.spec for k, v in by_name.items() if "final_norm" in k)
    assert all(x is None for x in norm)


@requires_axis_types
def test_moe_expert_specs():
    mesh = _mesh11()
    cfg = registry.get_reduced("mixtral-8x22b")
    enc = EncodingConfig(enabled=True, backend="xla")
    params = jax.eval_shape(lambda k: T.model_init(k, cfg, enc), jax.random.PRNGKey(0))
    sh = sharding.params_shardings(params, mesh)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    for path, s in flat:
        key = jax.tree_util.keystr(path)
        if "moe" in key and "w_gate" in key:
            # (G, E, N1, K1, N0, K0): N1 -> model (TP within expert).
            assert "model" in str(s.spec[2])
            break
    else:
        pytest.fail("no MoE expert weight found")


_SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import registry
    from repro.core.packed import EncodingConfig
    from repro.models import transformer as T
    from repro.parallel import sharding
    from repro.train import optimizer as opt_lib, trainer as trainer_lib
    from repro.data import pipeline as data_lib

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = registry.get_reduced("qwen2-1.5b")
    enc = EncodingConfig(enabled=True, backend="xla", shard_multiple=2)
    with jax.set_mesh(mesh):
        params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
        p_sh = sharding.params_shardings(params, mesh)
        params = jax.device_put(params, p_sh)
        opt_state = opt_lib.init(params)
        opt_cfg = opt_lib.OptimizerConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=20)
        data = data_lib.SyntheticPacked(
            data_lib.DataConfig(cfg.vocab_size, seq_len=16, global_batch=8))
        step = jax.jit(trainer_lib.make_train_step(cfg, enc, opt_cfg))
        losses = []
        for i in range(4):
            batch = jax.device_put(
                data.batch(i), sharding.batch_shardings(
                    jax.tree.map(jnp.asarray, data.batch(i)), mesh))
            params, opt_state, m, _ = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
        # params stayed sharded (not replicated):
        wq = params["groups"][0]["attn"]["wq"]["w_packed"]
        assert not wq.sharding.is_fully_replicated, wq.sharding
        assert all(np.isfinite(l) for l in losses), losses
        print("SPMD_OK", losses[0], losses[-1])
""")


@requires_axis_types
def test_spmd_multidevice_train_subprocess():
    """Real 8-device SPMD training steps (4x2 mesh) in a subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    r = subprocess.run(
        [sys.executable, "-c", _SPMD_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "SPMD_OK" in r.stdout


_DECODE_SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs import registry
    from repro.core.packed import EncodingConfig
    from repro.core.encoding import Phase
    from repro.models import transformer as T
    from repro.parallel import sharding

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = registry.get_reduced("mixtral-8x22b", capacity_factor=8.0)
    enc = EncodingConfig(enabled=True, backend="xla", shard_multiple=2)
    with jax.set_mesh(mesh):
        params = jax.device_put(
            T.model_init(jax.random.PRNGKey(0), cfg, enc),
            sharding.params_shardings(
                jax.eval_shape(lambda k: T.model_init(k, cfg, enc), jax.random.PRNGKey(0)),
                mesh))
        caches = jax.device_put(
            T.cache_init(cfg, 4, 32),
            sharding.cache_shardings(jax.eval_shape(lambda: T.cache_init(cfg, 4, 32)), mesh))
        toks = jnp.ones((4, 8), jnp.int32)
        logits, caches, _ = jax.jit(
            lambda p, t, c: T.forward(p, {"tokens": t}, cfg=cfg, enc=enc,
                                      phase=Phase.PREFILL, caches=c)
        )(params, toks, caches)
        tok = jnp.ones((4, 1), jnp.int32)
        logits2, caches, _ = jax.jit(
            lambda p, t, c: T.forward(p, {"tokens": t}, cfg=cfg, enc=enc,
                                      phase=Phase.DECODE, caches=c, pos=8)
        )(params, tok, caches)
        assert bool(jnp.isfinite(logits2).all())
        print("DECODE_SPMD_OK")
""")


@requires_axis_types
def test_spmd_decode_subprocess():
    """Sharded MoE prefill+decode on 8 devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    r = subprocess.run(
        [sys.executable, "-c", _DECODE_SPMD_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "DECODE_SPMD_OK" in r.stdout
