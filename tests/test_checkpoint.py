"""Fault tolerance: atomic checkpointing, bitwise restart, corruption
detection, async overlap, reshard-on-restore, elastic planning."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs import registry
from repro.core.packed import EncodingConfig
from repro.data import pipeline as data_lib
from repro.models import transformer as T
from repro.runtime import elastic, watchdog as wd_lib
from repro.train import optimizer as opt_lib
from repro.train import trainer as trainer_lib

# jax 0.4.37 (the pinned CI minimum) predates jax.sharding.AxisType /
# make_mesh(axis_types=...): these tests exercise the newer-jax SPMD API
# and skip on the pinned leg (they run on the latest-jax CI leg).
requires_axis_types = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType not available on this jax version",
)

ENC = EncodingConfig(enabled=True, backend="xla")


def _tiny_state(seed=0):
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(seed), cfg, ENC)
    return cfg, {"params": params, "opt": opt_lib.init(params)}


def test_save_restore_bitwise(tmp_path):
    cfg, state = _tiny_state()
    ckpt_lib.save(str(tmp_path), state, step=7)
    assert ckpt_lib.latest_step(str(tmp_path)) == 7
    restored = ckpt_lib.restore(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_resumes_identically(tmp_path):
    """Kill-and-restart: training continued from a checkpoint is bitwise
    identical to uninterrupted training (deterministic data keyed by step)."""
    cfg, state = _tiny_state()
    opt_cfg = opt_lib.OptimizerConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=50)
    data = data_lib.SyntheticPacked(
        data_lib.DataConfig(cfg.vocab_size, seq_len=16, global_batch=4)
    )
    step = jax.jit(trainer_lib.make_train_step(cfg, ENC, opt_cfg))

    # Continuous run: 6 steps.
    p, o = state["params"], state["opt"]
    for i in range(6):
        p, o, _, _ = step(p, o, jax.tree.map(jnp.asarray, data.batch(i)))

    # Interrupted run: 3 steps, checkpoint, "crash", restore, 3 more.
    p2, o2 = state["params"], state["opt"]
    for i in range(3):
        p2, o2, _, _ = step(p2, o2, jax.tree.map(jnp.asarray, data.batch(i)))
    ckpt_lib.save(str(tmp_path), {"params": p2, "opt": o2}, step=3)
    del p2, o2  # crash
    rs = ckpt_lib.restore(str(tmp_path), 3, state)
    p3, o3 = rs["params"], rs["opt"]
    for i in range(3, 6):
        p3, o3, _, _ = step(p3, o3, jax.tree.map(jnp.asarray, data.batch(i)))

    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corruption_detected(tmp_path):
    cfg, state = _tiny_state()
    path = ckpt_lib.save(str(tmp_path), state, step=1)
    victim = os.path.join(path, "leaf_00003.npy")
    with open(victim, "r+b") as f:
        f.seek(128)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(IOError, match="checksum"):
        ckpt_lib.restore(str(tmp_path), 1, state)


def test_atomicity_no_partial_checkpoint(tmp_path):
    """A .tmp dir (simulated crash mid-save) is never listed as a step."""
    cfg, state = _tiny_state()
    ckpt_lib.save(str(tmp_path), state, step=1)
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert ckpt_lib.latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    cfg, state = _tiny_state()
    saver = ckpt_lib.AsyncCheckpointer(str(tmp_path))
    saver.save(state, 5)
    saver.wait()
    assert ckpt_lib.latest_step(str(tmp_path)) == 5
    restored = ckpt_lib.restore(str(tmp_path), 5, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@requires_axis_types
def test_reshard_restore(tmp_path):
    """Restore with explicit shardings (single-device mesh here; the path is
    the same one the 512->256 elastic reshard takes)."""
    from repro.parallel import sharding

    cfg, state = _tiny_state()
    ckpt_lib.save(str(tmp_path), state, step=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sh = {
        "params": sharding.params_shardings(state["params"], mesh),
        "opt": {
            "mu": sharding.params_shardings(state["opt"]["mu"], mesh),
            "nu": sharding.params_shardings(state["opt"]["nu"], mesh),
            "step": sharding.replicated(mesh),
        },
    }
    restored = ckpt_lib.restore(str(tmp_path), 2, state, shardings=sh)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---- runtime: watchdog + elastic -------------------------------------------


def test_watchdog_flags_straggler():
    t = {"now": 0.0}
    wd = wd_lib.StepWatchdog(clock=lambda: t["now"])
    for i in range(10):
        wd.step_start()
        t["now"] += 1.0
        host_times = {0: 1.0, 1: 1.0, 2: 5.0 if i >= 6 else 1.0}
        wd.step_end(host_times=host_times)
    assert 2 in wd.evicted
    assert wd.should_remesh()
    assert 0 not in wd.evicted and 1 not in wd.evicted


def test_watchdog_tolerates_transient():
    t = {"now": 0.0}
    wd = wd_lib.StepWatchdog(clock=lambda: t["now"])
    for i in range(10):
        wd.step_start()
        t["now"] += 1.0
        host_times = {0: 1.0, 1: 4.0 if i == 6 else 1.0}  # one-off blip
        wd.step_end(host_times=host_times)
    assert not wd.evicted


def test_data_reassignment():
    r = wd_lib.DataReassigner(4)
    r.evict(2)
    shards = sum((r.shards_for(h) for h in range(4)), [])
    assert sorted(shards) == [0, 1, 2, 3]
    assert r.shards_for(2) == []


def test_elastic_plan():
    p = elastic.plan(512)
    assert p.data * p.model == 512 and p.model == 16
    p = elastic.plan(240, prefer_model_parallel=16)  # 16 doesn't divide 240
    assert p.data * p.model == 240
    p = elastic.plan(7)
    assert p.data * p.model == 7


@requires_axis_types
def test_elastic_resume(tmp_path):
    cfg, state = _tiny_state()
    ckpt_lib.save(str(tmp_path), state, step=9)
    mesh = elastic.plan(1).make_mesh()
    restored, step = elastic.resume(str(tmp_path), state, mesh)
    assert step == 9
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
