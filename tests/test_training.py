"""Training-loop behaviour: loss decreases, microbatch-accumulation
equivalence, gradient-compression convergence, optimizer invariants."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.packed import EncodingConfig
from repro.data import pipeline as data_lib
from repro.models import transformer as T
from repro.parallel import compression
from repro.train import optimizer as opt_lib
from repro.train import trainer as trainer_lib

ENC = EncodingConfig(enabled=True, backend="xla")


def _setup(arch="qwen2-1.5b", lr=3e-3, **kw):
    cfg = registry.get_reduced(arch)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    opt_state = opt_lib.init(params)
    opt_cfg = opt_lib.OptimizerConfig(peak_lr=lr, warmup_steps=2, decay_steps=100)
    data = data_lib.SyntheticPacked(
        data_lib.DataConfig(cfg.vocab_size, seq_len=32, global_batch=8)
    )
    return cfg, params, opt_state, opt_cfg, data


def test_loss_decreases():
    cfg, params, opt_state, opt_cfg, data = _setup()
    step = jax.jit(trainer_lib.make_train_step(cfg, ENC, opt_cfg))
    losses = []
    for i in range(30):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        params, opt_state, m, _ = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatch_equivalence():
    """grad-accum over 4 microbatches == single big batch (same update)."""
    cfg, params, opt_state, opt_cfg, data = _setup()
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    s1 = trainer_lib.make_train_step(cfg, ENC, opt_cfg, microbatches=1)
    s4 = trainer_lib.make_train_step(cfg, ENC, opt_cfg, microbatches=4)
    p1, _, m1, _ = s1(params, opt_state, batch)
    p4, _, m4, _ = s4(params, opt_state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        p1, p4,
    )
    assert max(jax.tree.leaves(diffs)) < 5e-5


def test_grad_compression_converges():
    """int8 + error feedback trains to (approximately) the same loss."""
    cfg, params, opt_state, opt_cfg, data = _setup()
    comp_state = compression.init_state(params)
    step_c = jax.jit(trainer_lib.make_train_step(cfg, ENC, opt_cfg, compress_grads=True))
    step_p = jax.jit(trainer_lib.make_train_step(cfg, ENC, opt_cfg))
    params_c, opt_c = params, opt_state
    params_p, opt_p = params, opt_state
    lc, lp = [], []
    for i in range(25):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        params_c, opt_c, mc, comp_state = step_c(params_c, opt_c, batch, comp_state)
        params_p, opt_p, mp, _ = step_p(params_p, opt_p, batch)
        lc.append(float(mc["loss"]))
        lp.append(float(mp["loss"]))
    assert np.mean(lc[-5:]) < np.mean(lc[:5]) - 0.1
    assert abs(np.mean(lc[-5:]) - np.mean(lp[-5:])) < 0.35, (lc[-5:], lp[-5:])


def test_quantize_roundtrip_error_bound():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(128, 64) * 5, jnp.float32)
    q, s = compression._quantize(x)
    err = jnp.abs(compression._dequantize(q, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-6


def test_gradient_clipping():
    cfg, params, opt_state, opt_cfg, data = _setup(lr=1.0)
    import dataclasses
    opt_cfg = dataclasses.replace(opt_cfg, clip_norm=1e-9)
    step = trainer_lib.make_train_step(cfg, ENC, opt_cfg)
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    new_params, _, m, _ = step(params, opt_state, batch)
    # With a tiny clip norm, the Adam direction is bounded, params move little.
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params, new_params,
    )
    assert max(jax.tree.leaves(diffs)) < 2.0  # lr * O(1) direction


def test_lr_schedule():
    cfg = opt_lib.OptimizerConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10, decay_steps=100)
    assert float(opt_lib.schedule(cfg, jnp.asarray(0))) < 0.2
    assert abs(float(opt_lib.schedule(cfg, jnp.asarray(10))) - 1.0) < 0.01
    assert float(opt_lib.schedule(cfg, jnp.asarray(100))) <= 0.11


def test_packed_padding_stays_zero_under_training():
    """The zero-padding invariant that makes shard_multiple safe."""
    import dataclasses
    cfg = registry.get_reduced("yi-9b")  # untied: has a packed head
    enc = EncodingConfig(enabled=True, backend="xla", shard_multiple=4)
    params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
    opt_state = opt_lib.init(params)
    opt_cfg = opt_lib.OptimizerConfig(peak_lr=1e-2, warmup_steps=1, decay_steps=10)
    data = data_lib.SyntheticPacked(
        data_lib.DataConfig(cfg.vocab_size, seq_len=16, global_batch=4)
    )
    step = jax.jit(trainer_lib.make_train_step(cfg, enc, opt_cfg))
    for i in range(3):
        params, opt_state, _, _ = step(params, opt_state, jax.tree.map(jnp.asarray, data.batch(i)))
    # head: (V, D) -> packed (N1,K1,128,128) with K padded (D=64 -> k0 tile 128).
    head = params["head"]["w_packed"]
    pad_region = np.asarray(head[..., :, 64:])  # K beyond true d_model
    assert np.all(pad_region == 0), "K-padding leaked nonzero values"
