"""Shared pytest fixtures.

The tier-1 suite runs as ONE process and jit-compiles thousands of XLA
executables (every engine config x phase x shape bucket keeps its own).
Each live executable holds several memory mappings, and a long run walks
the process into the kernel's vm.max_map_count ceiling (65530 by default)
— at which point an mmap inside XLA's compiler fails and the process
segfaults mid-compile, tens of minutes in.  Executables are only ever
shared within a test module (each module builds its own engines), so
dropping the jit caches at module boundaries bounds the peak map count at
"one module's worth" for the cost of re-tracing a handful of common
shapes per module.
"""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_xla_executable_footprint():
    yield
    jax.clear_caches()
