"""Tensor-parallel serving over a device mesh.

Deviceless units: build_serving_mesh error surface, the shard-aware kernel
quarantine table, and ShardedBlockAllocator mirroring.

Subprocess integration (``XLA_FLAGS=--xla_force_host_platform_device_count=4``
set before the first jax import — the same CPU emulation the CI
mesh-conformance job uses): mesh=2/4 decode must be token-identical to
mesh=1 across paged/dense x spec x token-budget, per-shard allocator audits
must stay exact through pool-pressure preemption, and a chaos schedule with a
shard-attributed kernel fault must demote ONLY that shard's quarantine entry
while the engine keeps serving.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.core.encoding import Phase
from repro.kernels import registry as registry_lib
from repro.launch import mesh as mesh_lib
from repro.serving import paged as paged_lib
from repro.serving.config import EngineConfig


# ---- build_serving_mesh ----------------------------------------------------

def test_serving_mesh_rejects_undersized_device_set():
    dev = jax.devices()[:1]
    with pytest.raises(ValueError) as ei:
        mesh_lib.build_serving_mesh((2,), devices=dev)
    msg = str(ei.value)
    # The error must be actionable: name the flag, never fall back to mesh=1.
    assert "xla_force_host_platform_device_count=2" in msg
    assert "2 devices" in msg


def test_serving_mesh_axis_naming():
    dev = jax.devices()[:1]
    m = mesh_lib.build_serving_mesh((1,), devices=dev)
    assert m.axis_names == ("model",)
    m2 = mesh_lib.build_serving_mesh((1, 1), devices=dev)
    assert m2.axis_names == ("data", "model")


def test_serving_mesh_rejects_bad_shapes():
    with pytest.raises(ValueError):
        mesh_lib.build_serving_mesh(())
    with pytest.raises(ValueError):
        mesh_lib.build_serving_mesh((0,))
    with pytest.raises(ValueError):
        mesh_lib.build_serving_mesh((1, 1, 1, 1))


def test_engine_config_mesh_fields():
    c = EngineConfig(mesh_shape=(2, 4))
    assert c.tp_shards == 4 and c.mesh_devices == 8
    with pytest.raises(ValueError, match="tp_axis"):
        EngineConfig(mesh_shape=(2,), tp_axis="rows")


# ---- shard-aware quarantine ------------------------------------------------

def test_shard_local_demotion_is_max_for_spmd_but_local_per_shard():
    registry_lib.clear_quarantine()
    try:
        key = registry_lib.attn_dispatch_key(Phase.DECODE, 64, "cpu")
        registry_lib.demote(key, failing="pallas", reason="chaos",
                            requested="pallas", shard=1)
        # The SPMD dispatch (shard=None) must honour the worst shard...
        assert registry_lib.quarantine_level(key) > 0
        # ...but shard 0's own view stays clean, shard 1's does not.
        assert registry_lib.quarantine_level(key, shard=0) == 0
        assert registry_lib.quarantine_level(key, shard=1) > 0
        snap = registry_lib.quarantine_snapshot()
        assert f"{key}@shard1" in snap
        assert snap[f"{key}@shard1"]["shard"] == 1
        assert key not in snap  # no global entry was created
    finally:
        registry_lib.clear_quarantine()


def test_global_demotion_applies_to_every_shard():
    registry_lib.clear_quarantine()
    try:
        key = registry_lib.attn_dispatch_key(Phase.DECODE, 64, "cpu")
        registry_lib.demote(key, failing="pallas", reason="global",
                            requested="pallas")
        assert registry_lib.quarantine_level(key, shard=0) > 0
        assert registry_lib.quarantine_level(key, shard=3) > 0
    finally:
        registry_lib.clear_quarantine()


# ---- ShardedBlockAllocator -------------------------------------------------

def test_sharded_allocator_mirrors_and_audits():
    alloc = paged_lib.ShardedBlockAllocator(16, 8, shards=2)
    assert alloc.capacity == paged_lib.BlockAllocator(16, 8).capacity
    assert len(alloc.shards) == 2
    pages = [alloc.alloc() for _ in range(3)]
    assert alloc.in_use() == 3
    assert alloc.stats["tp_shards"] == 2
    per = alloc.per_shard_stats()
    assert len(per) == 2 and per[0]["allocs"] == per[1]["allocs"] == 3
    alloc.audit([pages])
    alloc.free_pages(pages)
    alloc.audit([])


def test_sharded_allocator_detects_divergence():
    alloc = paged_lib.ShardedBlockAllocator(16, 8, shards=2)
    a = alloc.alloc()
    # Simulate a shard drifting out of lockstep (the invariant a real TP
    # deployment must never violate): free the page on ONE shard only.
    alloc.shards[1].free_page(a)
    with pytest.raises(paged_lib.AllocatorInvariantError, match="diverged"):
        alloc.alloc()


def test_sharded_allocator_per_shard_audit_failure_names_shard():
    alloc = paged_lib.ShardedBlockAllocator(16, 8, shards=2)
    a = alloc.alloc()
    alloc.shards[1].free_page(a)
    with pytest.raises(paged_lib.AllocatorInvariantError, match="shard 1"):
        alloc.audit([[a]])


# ---- multi-device SPMD integration (subprocess) ----------------------------

_ENV_HEADER = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import numpy as np
    from repro.configs import registry
    from repro.core.packed import EncodingConfig
    from repro.models import transformer as T
    from repro.serving import engine as engine_lib
    from repro.serving.config import EngineConfig

    ENC = EncodingConfig(enabled=True, backend="xla")
    # num_kv_heads=4 so the KV-head axis actually divides at 2 and 4 shards
    # (the stock reduced configs are GQA with a single KV head, which
    # sanitize correctly replicates — exercising the divisible case is the
    # point here).
    CFG = registry.get_reduced("qwen2-1.5b", num_kv_heads=4)
    PARAMS = T.model_init(jax.random.PRNGKey(0), CFG, ENC)

    def run(shards, *, prompts, max_new=6, audit_every_step=False, **kw):
        eng = engine_lib.Engine(
            PARAMS, CFG, ENC,
            config=EngineConfig(mesh_shape=(shards,), **kw))
        for i, p in enumerate(prompts):
            eng.submit(engine_lib.Request(
                uid=i, prompt=np.asarray(p, np.int32), max_new_tokens=max_new))
        if audit_every_step:
            while eng.queue or any(r is not None for r in eng.slot_req):
                eng.step()
                eng.audit()
        else:
            eng.run()
            eng.audit()
        assert all(r.status == "ok" for r in eng.finished), [
            (r.uid, r.status, r.error) for r in eng.finished]
        return {r.uid: list(r.generated) for r in eng.finished}, eng

    PROMPTS = [((np.arange(5 + 3 * i) * 7 + i) % (CFG.vocab_size - 1) + 1)
               for i in range(4)]
"""

_TP_IDENTITY_SCRIPT = textwrap.dedent(_ENV_HEADER + """
    MATRIX = [
        ("paged", dict(slots=2, max_seq=64, cache_mode="paged", block_size=8)),
        ("dense", dict(slots=2, max_seq=64, cache_mode="dense")),
        ("spec", dict(slots=2, max_seq=64, cache_mode="paged", block_size=8,
                      spec_decode=True, draft_k=3)),
        ("budget", dict(slots=2, max_seq=64, cache_mode="paged", block_size=8,
                        token_budget=16)),
        # Quantized paged KV: scale pages shard alongside their KV pages
        # (parallel/sharding.serving_cache_shardings); the xla attention
        # fallback dequantizes identically at every mesh degree, so kv8
        # serving must stay token-identical to its own mesh=1 run.
        ("kv8", dict(slots=2, max_seq=64, cache_mode="paged", block_size=8,
                     kv_quant="kv8")),
    ]
    for name, kw in MATRIX:
        base, beng = run(1, prompts=PROMPTS, **kw)
        for shards in (2, 4):
            got, eng = run(shards, prompts=PROMPTS, **kw)
            assert got == base, (name, shards, base, got)
            assert eng.tp_shards == shards
            assert eng.stats["tp"]["shards"] == shards
            if name == "kv8":
                assert eng.stats["kv_quant"] == "kv8"
        print("IDENT_OK", name)
    print("TP_IDENTITY_OK")
""")

_TP_PREEMPT_SCRIPT = textwrap.dedent(_ENV_HEADER + """
    # A pool too small for every request at once forces preemption + replay;
    # the mirrored per-shard allocators and per-shard audit must stay exact
    # through it, and output must still match mesh=1.
    kw = dict(slots=3, max_seq=64, cache_mode="paged", block_size=8,
              pool_pages=6)
    base, e1 = run(1, prompts=PROMPTS, max_new=8, audit_every_step=True, **kw)
    got, e2 = run(2, prompts=PROMPTS, max_new=8, audit_every_step=True, **kw)
    assert got == base, (base, got)
    assert e2.preemptions == e1.preemptions
    assert e1.preemptions > 0, "pool was meant to force preemption"
    per = e2.stats["tp"]["per_shard_pages"]
    assert per[0] == per[1], per  # lockstep shards: identical counters
    print("TP_PREEMPT_OK", e2.preemptions)
""")

_TP_CHAOS_SCRIPT = textwrap.dedent(_ENV_HEADER + """
    from repro.kernels import registry as registry_lib
    from repro.serving import faults as faults_lib

    sched = faults_lib.FaultSchedule(
        [faults_lib.Fault(2, "kernel_fail", key="attn|decode|*", shard=1)],
        seed=0)
    eng = engine_lib.Engine(
        PARAMS, CFG, ENC,
        config=EngineConfig(slots=2, max_seq=64, cache_mode="paged",
                            block_size=8, mesh_shape=(2,)),
        fault_hooks=sched, clock=sched.clock)
    for i, p in enumerate(PROMPTS):
        eng.submit(engine_lib.Request(
            uid=i, prompt=np.asarray(p, np.int32), max_new_tokens=6))
    eng.run()
    eng.audit()
    assert all(r.status == "ok" for r in eng.finished)

    # The demotion landed shard-local, not globally.
    snap = registry_lib.quarantine_snapshot()
    shard_keys = [k for k in snap if "@shard1" in k]
    assert shard_keys, snap
    assert all("@shard" in k or snap[k].get("shard") == 1 for k in snap), snap
    s = eng.stats
    assert s["lifecycle"]["kernel_faults"] == 1
    # Per-shard degradation trail: the fault shows on shard 1 only.
    assert s["degraded"][1] and not s["degraded"][0], s["degraded"]
    assert s["degraded"][1][0]["shard"] == 1
    # Shard 0 still resolves its requested rung; the SPMD dispatch honours
    # shard 1's demotion (max over shards).
    key = s["degraded"][1][0]["key"]
    assert registry_lib.quarantine_level(key, shard=0) == 0
    assert registry_lib.quarantine_level(key) > 0
    print("TP_CHAOS_OK")
""")


def _run_sub(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("XLA_FLAGS", None)  # the script sets its own device count
    r = subprocess.run(
        [sys.executable, "-c", script], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


def test_tp_token_identity_subprocess():
    """mesh=2/4 decode is token-identical to mesh=1 across paged/dense x
    spec x token-budget (4 emulated CPU devices)."""
    out = _run_sub(_TP_IDENTITY_SCRIPT)
    assert "TP_IDENTITY_OK" in out


def test_tp_preemption_audit_subprocess():
    """Per-shard allocator audits stay exact through preemption/replay on a
    2-shard mesh, with identical output and preemption count to mesh=1."""
    out = _run_sub(_TP_PREEMPT_SCRIPT)
    assert "TP_PREEMPT_OK" in out


def test_tp_shard_local_chaos_subprocess():
    """A kernel fault attributed to shard 1 demotes only that shard's
    quarantine entry; shard 0 stays clean and serving completes."""
    out = _run_sub(_TP_CHAOS_SCRIPT)
    assert "TP_CHAOS_OK" in out
