"""Per-kernel correctness: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encoding import Phase
from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _rand(shape, dtype, seed=0):
    x = np.random.RandomState(seed).randn(*shape)
    return jnp.asarray(x, dtype)


MNK_SWEEP = [
    (8, 16, 32),
    (6, 10, 7),          # ragged everything
    (1, 512, 256),       # decode GEMV shape
    (128, 128, 128),     # exactly one MXU tile
    (256, 384, 512),
    (200, 136, 264),     # ragged multi-tile
]


@pytest.mark.parametrize("mnk", MNK_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("backend", ["xla", "pallas", "fused"])
def test_encoded_matmul_matches_reference(mnk, dtype, backend):
    m, n, k = mnk
    x = _rand((m, k), dtype, seed=m + n)
    w_t = _rand((n, k), dtype, seed=k)
    rhs4 = ops.pack_rhs(w_t)
    want = ref.matmul_reference(
        x.astype(jnp.float32), w_t.astype(jnp.float32)
    )
    got = ops.encoded_matmul(
        x, rhs4, n=n, phase=Phase.PREFILL, backend=backend,
        out_dtype=jnp.float32, interpret=True,
    )
    assert got.shape == want.shape
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol * np.abs(want).max()
    )


@pytest.mark.parametrize("mnk", [(1, 256, 128), (4, 512, 384), (8, 640, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_gemv_kernel(mnk, dtype):
    m, n, k = mnk
    x = _rand((m, k), dtype)
    w_t = _rand((n, k), dtype, seed=3)
    rhs4 = ops.pack_rhs(w_t)
    want = ref.matmul_reference(x.astype(jnp.float32), w_t.astype(jnp.float32))
    got = ops.encoded_matmul(
        x, rhs4, n=n, phase=Phase.DECODE, backend="pallas",
        out_dtype=jnp.float32, interpret=True,
    )
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol * np.abs(want).max()
    )


@pytest.mark.parametrize("shape,tile", [
    ((128, 256), (8, 128)),
    ((64, 128), (16, 64)),
    ((256, 512), (128, 128)),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_pack_unpack_pallas_roundtrip(shape, tile, dtype):
    if dtype == jnp.int8:
        x = jnp.asarray(np.random.RandomState(0).randint(-127, 127, shape), dtype)
    else:
        x = _rand(shape, dtype)
    packed = ops.pack_pallas(x, tile=tile, interpret=True)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(ref.pack(x, tile)))
    unpacked = ops.unpack_pallas(packed, interpret=True)
    np.testing.assert_array_equal(np.asarray(unpacked), np.asarray(x))


@pytest.mark.parametrize("blocks", [(1, 1, 1), (2, 2, 2), (4, 1, 2)])
def test_mmt4d_kernel_blocks(blocks):
    m0 = n0 = k0 = 32
    bm, bn, bk = blocks
    lhs4 = _rand((4 * bm, 4 * bk, m0, k0), jnp.float32)
    rhs4 = _rand((4 * bn, 4 * bk, n0, k0), jnp.float32, seed=1)
    lhs4 = lhs4[:, : 4 * bk]
    want = ref.mmt4d(lhs4, rhs4)
    got = ops.mmt4d_pallas(lhs4, rhs4, blocks=blocks, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_f16_accumulates_in_f32():
    """The paper's microkernels are f16xf16->f32: check accumulation dtype."""
    m = n = 8
    k = 4096
    x = jnp.full((m, k), 0.01, jnp.float16)
    w_t = jnp.full((n, k), 0.01, jnp.float16)
    rhs4 = ops.pack_rhs(w_t)
    got = ops.encoded_matmul(
        x, rhs4, n=n, phase=Phase.PREFILL, backend="pallas",
        out_dtype=jnp.float32, interpret=True,
    )
    # f16 accumulation would saturate resolution well below the exact 0.4096.
    np.testing.assert_allclose(np.asarray(got), 0.4096, rtol=1e-3)
