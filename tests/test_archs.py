"""Per-assigned-architecture smoke tests (deliverable f): reduced config of
the same family, one forward + one train step on CPU, asserting output shapes
and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.train import optimizer as opt_lib
from repro.train import trainer as trainer_lib

ENC = EncodingConfig(enabled=True, backend="xla")


def _batch(cfg, b, s, with_labels=True, seed=0):
    rng = np.random.RandomState(seed)
    out = {"tokens": jnp.asarray(rng.randint(1, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            0.1 * rng.randn(b, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        out["patches"] = jnp.asarray(
            0.1 * rng.randn(b, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32
        )
    if with_labels:
        out["labels"] = jnp.asarray(rng.randint(1, cfg.vocab_size, (b, s)), jnp.int32)
    return out


@pytest.mark.parametrize("arch", registry.ASSIGNED_ARCHS)
def test_arch_forward_smoke(arch):
    cfg = registry.get_reduced(arch)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    b, s = 2, 16
    batch = _batch(cfg, b, s, with_labels=False)
    logits, _, aux = T.forward(params, batch, cfg=cfg, enc=ENC, phase=Phase.PREFILL)
    expect_s = s + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (b, expect_s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", registry.ASSIGNED_ARCHS)
def test_arch_train_step_smoke(arch):
    cfg = registry.get_reduced(arch)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    opt_state = opt_lib.init(params)
    step = trainer_lib.make_train_step(cfg, ENC, opt_lib.OptimizerConfig(peak_lr=1e-3))
    batch = _batch(cfg, 2, 16)
    new_params, new_opt, metrics, _ = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    # Parameters actually moved.
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params, new_params,
    )
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-1.6b", "recurrentgemma-9b", "mixtral-8x22b"])
def test_arch_decode_smoke(arch):
    cfg = registry.get_reduced(arch)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    b, s = 2, 8
    caches = T.cache_init(cfg, b, max_seq=32)
    batch = _batch(cfg, b, s, with_labels=False)
    _, caches, _ = T.forward(params, batch, cfg=cfg, enc=ENC, phase=Phase.PREFILL, caches=caches)
    tok = jnp.ones((b, 1), jnp.int32)
    logits, caches, _ = T.forward(
        params, {"tokens": tok}, cfg=cfg, enc=ENC, phase=Phase.DECODE, caches=caches, pos=s
    )
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters (spot checks per arch)."""
    c = registry.get_config("mixtral-8x22b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.num_experts, c.experts_per_token) == (
        56, 6144, 48, 8, 16384, 32768, 8, 2)
    c = registry.get_config("grok-1-314b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == (64, 6144, 32768, 131072)
    c = registry.get_config("qwen2.5-14b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.qkv_bias) == (48, 5120, 40, 8, 13824, 152064, True)
    c = registry.get_config("qwen2.5-32b")
    assert (c.num_layers, c.d_ff) == (64, 27648)
    c = registry.get_config("qwen2-1.5b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (28, 1536, 12, 2, 8960, 151936)
    c = registry.get_config("yi-9b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (48, 4096, 32, 4, 11008, 64000)
    c = registry.get_config("whisper-tiny")
    assert (c.num_layers, c.encoder_layers, c.d_model, c.num_heads, c.d_ff,
            c.vocab_size) == (4, 4, 384, 6, 1536, 51865)
    c = registry.get_config("rwkv6-1.6b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == (24, 2048, 7168, 65536)
    c = registry.get_config("recurrentgemma-9b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) == (
        38, 4096, 16, 12288, 256000)
    assert c.block_pattern == ("rec", "rec", "attn")
    c = registry.get_config("internvl2-26b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (48, 6144, 48, 8, 16384, 92553)


def test_long_500k_gating():
    """long_500k runs exactly for the sub-quadratic archs (DESIGN.md §4)."""
    runnable = {
        a for a, s, ok, _ in registry.all_cells() if s == "long_500k" and ok
    }
    assert runnable == {"mixtral-8x22b", "rwkv6-1.6b", "recurrentgemma-9b"}
    assert len(registry.all_cells()) == 40
