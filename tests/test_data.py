"""Data pipeline: determinism, host-shard disjointness, packing validity."""

import pytest

hypothesis = pytest.importorskip("hypothesis")  # container may lack it
import hypothesis.strategies as st
import numpy as np

from repro.data import pipeline as data_lib


def _cfg(**kw):
    base = dict(vocab_size=512, seq_len=32, global_batch=8, seed=3)
    base.update(kw)
    return data_lib.DataConfig(**base)


def test_deterministic_across_instances():
    a = data_lib.SyntheticPacked(_cfg()).batch(5)
    b = data_lib.SyntheticPacked(_cfg()).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_steps_differ():
    d = data_lib.SyntheticPacked(_cfg())
    assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])


def test_host_sharding_disjoint_and_covering():
    """num_hosts shards concatenated == the single-host global batch."""
    full = data_lib.SyntheticPacked(_cfg()).batch(2)["tokens"]
    parts = [
        data_lib.SyntheticPacked(_cfg(), host_id=h, num_hosts=4).batch(2)["tokens"]
        for h in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_labels_are_shifted_tokens():
    d = data_lib.SyntheticPacked(_cfg())
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@hypothesis.given(seed=st.integers(0, 1000), step=st.integers(0, 100))
@hypothesis.settings(max_examples=20, deadline=None)
def test_tokens_in_vocab_property(seed, step):
    d = data_lib.SyntheticPacked(_cfg(seed=seed))
    b = d.batch(step)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 512
    assert b["tokens"].shape == (8, 32)


def test_prefetcher_preserves_order():
    d = data_lib.SyntheticPacked(_cfg())
    pf = data_lib.Prefetcher(d)
    got = [next(pf)["tokens"] for _ in range(3)]
    want = [d.batch(i)["tokens"] for i in range(3)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
