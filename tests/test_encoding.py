"""Encoding-layer invariants: the paper's tile rule, VMEM budgeting, and
pack/unpack round-trip properties (hypothesis)."""

import pytest

hypothesis = pytest.importorskip("hypothesis")  # container may lack it
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from repro.core import encoding, targets
from repro.core.encoding import Phase
from repro.kernels import ops, ref


def test_paper_tile_rule_prefill():
    """Methodology step 1(a): prefill M,N,K = 6, VLEN/8, 1 at VLEN=256."""
    t = encoding.paper_tile_sizes(Phase.PREFILL, vlen_bits=256)
    assert t.as_tuple() == (6, 32, 1)


def test_paper_tile_rule_decode():
    """Methodology step 1(b): decode M,N,K = 1, VLEN/4, 1 at VLEN=256."""
    t = encoding.paper_tile_sizes(Phase.DECODE, vlen_bits=256)
    assert t.as_tuple() == (1, 64, 1)


def test_riscv_target_reproduces_paper_tiles():
    """select_tile_sizes pointed at the paper's hardware == published tiles."""
    for phase in (Phase.PREFILL, Phase.DECODE):
        got = encoding.select_tile_sizes(phase, target=targets.RISCV_VLEN256)
        assert got == encoding.paper_tile_sizes(phase)


def test_tpu_tiles_are_mxu_aligned():
    t = encoding.select_tile_sizes(Phase.PREFILL, lhs_dtype=jnp.bfloat16)
    assert t.m0 % 128 == 0 and t.n0 % 128 == 0 and t.k0 % 128 == 0


def test_decode_tiles_widen_n():
    """The paper's GEMV rule: decode trades M for wide N (weight streaming)."""
    p = encoding.select_tile_sizes(Phase.PREFILL)
    d = encoding.select_tile_sizes(Phase.DECODE, m_hint=1)
    assert d.m0 < p.m0 and d.n0 > p.n0


@hypothesis.given(
    m1=st.integers(1, 64), n1=st.integers(1, 64), k1=st.integers(1, 64),
    phase=st.sampled_from([Phase.PREFILL, Phase.DECODE, Phase.TRAIN]),
)
@hypothesis.settings(max_examples=50, deadline=None)
def test_kernel_blocks_fit_vmem(m1, n1, k1, phase):
    """The register-spill rule, re-solved for VMEM: selected blocks always fit
    the budget and always divide nothing larger than the grid."""
    tiles = encoding.select_tile_sizes(phase)
    kb = encoding.select_kernel_blocks(tiles, phase, m1=m1, n1=n1, k1=k1)
    assert 1 <= kb.bm1 <= m1 and 1 <= kb.bn1 <= n1 and 1 <= kb.bk1 <= k1
    lhs = kb.bm1 * kb.bk1 * tiles.m0 * tiles.k0 * 2
    rhs = kb.bn1 * kb.bk1 * tiles.n0 * tiles.k0 * 2
    acc = kb.bm1 * kb.bn1 * tiles.m0 * tiles.n0 * 4
    assert lhs + rhs + acc <= targets.TPU_V5E.vmem_bytes * 0.5


@hypothesis.given(
    r=st.integers(1, 300), c=st.integers(1, 300),
    t0=st.sampled_from([1, 2, 6, 8, 16, 128]),
    t1=st.sampled_from([1, 2, 8, 32, 128]),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_pack_unpack_roundtrip_property(r, c, t0, t1):
    x = jnp.arange(r * c, dtype=jnp.float32).reshape(r, c)
    assert np.array_equal(np.asarray(ref.unpack(ref.pack(x, (t0, t1)), (r, c))), np.asarray(x))


@hypothesis.given(
    m=st.integers(1, 40), n=st.integers(1, 40), k=st.integers(1, 40),
    phase=st.sampled_from([Phase.PREFILL, Phase.DECODE]),
)
@hypothesis.settings(max_examples=30, deadline=None)
def test_encoded_matmul_equals_reference_property(m, n, k, phase):
    """The paper's Table-1 invariant at the op level: the encoded path is
    numerically the reference contraction (f32, xla backend: exact op
    identity up to reduction order)."""
    rng = np.random.RandomState(m * 1000 + n * 10 + k)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
    rhs4 = ops.pack_rhs(w_t)
    want = ref.matmul_reference(x, w_t)
    got = ops.encoded_matmul(
        x, rhs4, n=n, phase=phase, backend="xla", out_dtype=jnp.float32
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_shard_multiple_padding_is_zero_and_sliced():
    w_t = jnp.ones((100, 70), jnp.float32)
    p4 = ops.pack_rhs(w_t, shard_multiple=16)
    assert p4.shape[0] % 16 == 0 and p4.shape[1] % 16 == 0
    x = jnp.ones((4, 70), jnp.float32)
    got = ops.encoded_matmul(x, p4, n=100, phase=Phase.PREFILL, backend="xla",
                             out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), 70.0)


def test_block_selector_near_optimal_intensity():
    """The paper's tile-size claim, quantified: the VMEM-model selection is
    within 10% of the best feasible arithmetic intensity (benchmarks/
    ablation_tiles.py sweeps the full block space)."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import ablation_tiles

    rows, tiles, grid = ablation_tiles.sweep()
    sel = encoding.select_kernel_blocks(
        encoding.TileSizes(*tiles), Phase.PREFILL,
        m1=grid[0], n1=grid[1], k1=grid[2],
    )
    best = max((r for r in rows if r[4]), key=lambda r: r[6])
    sel_row = next(r for r in rows if (r[0], r[1], r[2]) == (sel.bm1, sel.bn1, sel.bk1))
    assert sel_row[4], "selected blocks must fit VMEM"
    assert sel_row[6] / best[6] >= 0.9
