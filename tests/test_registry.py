"""Dispatch registry (kernels/registry.py): key resolution, tuned-table JSON
round-trip, unknown-key fallback, and registry-vs-direct-call output parity
across all quant modes."""

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import targets as targets_lib
from repro.core.encoding import Phase
from repro.kernels import ops, registry


@pytest.fixture(autouse=True)
def _fresh_table_cache():
    registry.clear_cache()
    yield
    registry.clear_cache()


def test_m_bucket_boundaries():
    assert registry.m_bucket(1) == "m1"
    assert registry.m_bucket(2) == "m8"
    assert registry.m_bucket(8) == "m8"
    assert registry.m_bucket(9) == "m32"
    assert registry.m_bucket(32) == "m32"
    assert registry.m_bucket(33) == "m64"
    assert registry.m_bucket(64) == "m64"
    assert registry.m_bucket(65) == "big"


def test_verify_bucket_routes_to_mmt4d_not_gemv(tmp_path):
    """The spec-decode verify regime (m32: slots x draft window) must route
    to the packed mmt4d GEMM, not the VMEM-row-resident fused GEMV — both by
    static policy and in the checked-in tuned table."""
    for quant in registry.QUANTS:
        # Monotonic in M: GEMV-like row counts keep the fused GEMV, all
        # multi-row decode (verify window and beyond) routes mmt4d.
        assert registry.default_backend(quant, Phase.DECODE, "m1") == "fused"
        assert registry.default_backend(quant, Phase.DECODE, "m8") == "fused"
        assert registry.default_backend(quant, Phase.DECODE, "m32") == "pallas"
        assert registry.default_backend(quant, Phase.DECODE, "m64") == "pallas"
        # The token-budget mixed step packs slots x window rows — "big" must
        # stay on the GEMM side of the monotonic policy, not fall through to
        # the fused GEMV like it once did.
        assert registry.default_backend(quant, Phase.DECODE, "big") == "pallas"
    # A target that measured the fused GEMV faster at a multi-row bucket
    # overrides the policy through its tuned entry (tpu-v5e m64).
    m64 = registry.select(quant="none", phase=Phase.DECODE, m=48)
    assert m64.backend == "fused" and m64.source == "tuned"
    # Policy applies when no tuned entry exists (empty table)...
    empty = str(tmp_path / "empty.json")
    registry.save_table({"entries": {}}, empty)
    choice = registry.select(
        quant="none", phase=Phase.DECODE, m=20, table_path=empty
    )
    assert choice.backend == "pallas" and choice.source == "default"
    # ...and the committed tuned table agrees for every quant mode.
    for quant in registry.QUANTS:
        tuned = registry.select(quant=quant, phase=Phase.DECODE, m=20)
        assert tuned.backend == "pallas", quant


def test_unknown_target_falls_back_to_reference():
    weird = dataclasses.replace(targets_lib.TPU_V5E, name="weird-accelerator")
    choice = registry.select(quant="none", phase=Phase.DECODE, m=1, target=weird)
    assert choice.backend == "reference"
    assert choice.source == "fallback"
    assert choice.blocks is None


def test_unknown_quant_falls_back_to_reference():
    choice = registry.select(quant="w2a2", phase=Phase.DECODE, m=1)
    assert choice.backend == "reference"
    assert choice.source == "fallback"


def test_quant_fallback_is_oracle_backend():
    """For quantized modes the no-data fallback is the xla oracle path."""
    weird = dataclasses.replace(targets_lib.TPU_V5E, name="weird-accelerator")
    for quant in ("w8a8", "w4a8"):
        choice = registry.select(quant=quant, phase=Phase.DECODE, m=4, target=weird)
        assert choice.backend == "xla", quant


def test_requested_backend_always_wins(tmp_path):
    """An explicit backend= pins the path even when a tuned entry disagrees."""
    path = str(tmp_path / "table.json")
    key = registry.dispatch_key("none", Phase.DECODE, 4, "tpu-v5e")
    registry.save_table(
        {"entries": {key: {"backend": "xla", "blocks": [1, 2, 1]}}}, path
    )
    choice = registry.select(
        quant="none", phase=Phase.DECODE, m=4, requested="fused", table_path=path
    )
    assert choice.backend == "fused"
    assert choice.source == "requested"
    # ...but tuned blocks still flow in when the caller supplied none.
    assert choice.blocks == (1, 2, 1)


def test_tuned_table_json_roundtrip(tmp_path):
    path = str(tmp_path / "table.json")
    entries = {
        registry.dispatch_key("w4a8", Phase.DECODE, 8, "tpu-v5e"): {
            "backend": "fused", "blocks": [1, 4, 1], "us": 12.5,
        },
        registry.dispatch_key("w8a8", Phase.PREFILL, 128, "tpu-v5e"): {
            "backend": "pallas", "blocks": [2, 2, 2],
        },
    }
    registry.save_table({"entries": entries}, path)
    registry.clear_cache()
    loaded = registry.load_table(path)
    assert loaded["entries"] == json.loads(json.dumps(entries))  # value-identical
    choice = registry.select(quant="w4a8", phase=Phase.DECODE, m=8, table_path=path)
    assert choice.backend == "fused"
    assert choice.blocks == (1, 4, 1)
    assert choice.source == "tuned"


def test_corrupt_table_falls_back_to_policy(tmp_path):
    path = str(tmp_path / "table.json")
    with open(path, "w") as f:
        f.write("{not json")
    choice = registry.select(quant="none", phase=Phase.DECODE, m=1, table_path=path)
    assert choice.backend == "fused"  # static default policy, not a crash
    assert choice.source == "default"


def test_checked_in_table_is_loadable_and_typed():
    """The committed tuned_table.json parses and every entry is well-formed
    (both op classes: matmul quant keys and attn|phase|S-bucket keys)."""
    table = registry.load_table()
    assert table["entries"], "checked-in tuned table should not be empty"
    seen_attn = 0
    for key, entry in table["entries"].items():
        head = key.split("|", 1)[0]
        b = entry["blocks"]
        if head == registry.ATTN_OP:
            # Attn keys are 4-part (legacy, implied bf16) or 5-part (with
            # the kv-quant axis); split_attn_key validates either form.
            seen_attn += 1
            _phase, bucket, kv, _target = registry.split_attn_key(key)
            assert bucket in registry.S_BUCKETS, key
            assert kv in registry.KV_QUANTS, key
            assert entry["backend"] in registry.ATTN_BACKENDS, key
            assert len(b) == 2 and all(isinstance(v, int) and v >= 1 for v in b), key
        else:
            head, phase, bucket, target = key.split("|")
            assert head in registry.QUANTS, key
            assert bucket in registry.M_BUCKETS, key
            assert entry["backend"] in registry.BACKENDS_BY_QUANT[head], key
            assert len(b) == 3 and all(isinstance(v, int) and v >= 1 for v in b), key
    assert seen_attn, "tuned table must cover the attention op class"


@pytest.mark.parametrize("phase", [Phase.DECODE, Phase.PREFILL])
def test_registry_vs_direct_call_parity_all_quants(phase):
    """backend="auto" (registry-resolved) must produce the same output as the
    direct explicit-backend call it resolves to, for every quant mode."""
    m = 4 if phase is Phase.DECODE else 40
    n, k = 384, 256
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    w_t = jnp.asarray(rng.randn(n, k), jnp.float32)

    rhs4 = ops.pack_rhs(w_t)
    rhs4_q, s_w = ops.pack_rhs_q8(w_t)
    rhs4_p, s_w4 = ops.pack_rhs_q4(w_t)

    cases = {
        "none": (
            lambda be: ops.encoded_matmul(
                x, rhs4, n=n, phase=phase, backend=be,
                out_dtype=jnp.float32, interpret=True,
            )
        ),
        "w8a8": (
            lambda be: ops.encoded_matmul_q8(
                x, rhs4_q, s_w, n=n, phase=phase, backend=be,
                out_dtype=jnp.float32, interpret=True,
            )
        ),
        "w4a8": (
            lambda be: ops.encoded_matmul_q4(
                x, rhs4_p, s_w4, n=n, phase=phase, backend=be,
                out_dtype=jnp.float32, interpret=True,
            )
        ),
    }
    for quant, call in cases.items():
        resolved = registry.select(quant=quant, phase=phase, m=m)
        auto = call("auto")
        direct = call(resolved.backend)
        np.testing.assert_array_equal(
            np.asarray(auto), np.asarray(direct), err_msg=f"{quant}/{phase}"
        )
        # And the resolved path agrees numerically with the oracle backend.
        oracle = call("xla" if quant != "none" else "reference")
        np.testing.assert_allclose(
            np.asarray(auto), np.asarray(oracle), rtol=2e-4, atol=2e-4,
            err_msg=f"{quant}/{phase} vs oracle",
        )


# ---------------------------------------------------------------------------
# Attention op class (select_attn)


def test_s_bucket_boundaries():
    assert registry.s_bucket(1) == "s256"
    assert registry.s_bucket(256) == "s256"
    assert registry.s_bucket(257) == "s1k"
    assert registry.s_bucket(1024) == "s1k"
    assert registry.s_bucket(1025) == "s4k"
    assert registry.s_bucket(4096) == "s4k"
    assert registry.s_bucket(4097) == "sbig"


def test_attn_requested_backend_wins_and_invalid_raises(tmp_path):
    empty = str(tmp_path / "empty.json")
    registry.save_table({"entries": {}}, empty)
    for be in registry.ATTN_BACKENDS:
        choice = registry.select_attn(
            phase=Phase.DECODE, s=64, requested=be, table_path=empty
        )
        assert choice.backend == be and choice.source == "requested"
    with pytest.raises(ValueError):
        registry.select_attn(phase=Phase.DECODE, s=64, requested="fused")


def test_attn_policy_and_tuned_resolution(tmp_path):
    # Static policy on an empty table: pallas for every phase/bucket.
    empty = str(tmp_path / "empty.json")
    registry.save_table({"entries": {}}, empty)
    for phase in (Phase.DECODE, Phase.PREFILL):
        for s in (64, 512, 2048, 9000):
            choice = registry.select_attn(phase=phase, s=s, table_path=empty)
            assert choice.backend == "pallas" and choice.source == "default"
    # A tuned entry (2-int blocks = (q_chunk, kv_chunk)) outranks the policy.
    path = str(tmp_path / "table.json")
    key = registry.attn_dispatch_key(Phase.DECODE, 512, "tpu-v5e")
    registry.save_table(
        {"entries": {key: {"backend": "xla", "blocks": [1, 64]}}}, path
    )
    choice = registry.select_attn(phase=Phase.DECODE, s=512, table_path=path)
    assert choice.backend == "xla" and choice.source == "tuned"
    assert choice.blocks == (1, 64)
    # Explicit blocks= beat tuned blocks (mirrors the matmul class).
    choice = registry.select_attn(
        phase=Phase.DECODE, s=512, blocks=(1, 32), table_path=path
    )
    assert choice.blocks == (1, 32)


def test_attn_unknown_target_falls_back_to_xla(tmp_path):
    empty = str(tmp_path / "empty.json")
    registry.save_table({"entries": {}}, empty)
    alien = dataclasses.replace(targets_lib.TPU_V5E, name="gpu-h100")
    choice = registry.select_attn(
        phase=Phase.DECODE, s=512, target=alien, table_path=empty
    )
    assert choice.backend == "xla" and choice.source == "fallback"


def test_attn_key_kv_axis_forms():
    """bf16 keys keep the legacy 4-segment form; kv8/kv4 insert the kv axis
    before the target.  split_attn_key inverts both and rejects junk."""
    k_bf16 = registry.attn_dispatch_key(Phase.DECODE, 512, "tpu-v5e")
    assert k_bf16 == "attn|decode|s1k|tpu-v5e"
    assert registry.split_attn_key(k_bf16) == ("decode", "s1k", "bf16", "tpu-v5e")
    k8 = registry.attn_dispatch_key(Phase.DECODE, 512, "tpu-v5e", kv="kv8")
    assert k8 == "attn|decode|s1k|kv8|tpu-v5e"
    assert registry.split_attn_key(k8) == ("decode", "s1k", "kv8", "tpu-v5e")
    assert registry.attn_dispatch_key(
        Phase.PREFILL, 64, "tpu-v5e", kv="bf16"
    ) == "attn|prefill|s256|tpu-v5e"
    with pytest.raises(ValueError):
        registry.attn_dispatch_key(Phase.DECODE, 512, "tpu-v5e", kv="kv2")
    with pytest.raises(ValueError, match="malformed attn key"):
        registry.split_attn_key("attn|decode|s1k|not-a-kv|x|y")
    with pytest.raises(ValueError):
        registry.split_attn_key("none|decode|m8|tpu-v5e")


def test_attn_kv_key_inherits_bf16_tuned_blocks(tmp_path):
    """A kv8/kv4 key with no tuned entry of its own falls back to the
    legacy bf16 entry's blocks (chunk geometry is dtype-independent), while
    an exact 5-part entry outranks the inherited one."""
    path = str(tmp_path / "table.json")
    key_bf16 = registry.attn_dispatch_key(Phase.DECODE, 512, "tpu-v5e")
    registry.save_table(
        {"entries": {key_bf16: {"backend": "pallas", "blocks": [1, 64]}}},
        path,
    )
    choice = registry.select_attn(
        phase=Phase.DECODE, s=512, kv="kv8", table_path=path
    )
    assert choice.source == "tuned" and choice.blocks == (1, 64)
    # Exact kv-specific entry wins over the inherited bf16 one.
    key_kv8 = registry.attn_dispatch_key(Phase.DECODE, 512, "tpu-v5e", kv="kv8")
    registry.save_table(
        {"entries": {
            key_bf16: {"backend": "pallas", "blocks": [1, 64]},
            key_kv8: {"backend": "xla", "blocks": [1, 32]},
        }},
        path,
    )
    registry.clear_cache()
    choice = registry.select_attn(
        phase=Phase.DECODE, s=512, kv="kv8", table_path=path
    )
    assert choice.backend == "xla" and choice.blocks == (1, 32)
    # The bf16 resolution is untouched by the kv8 entry.
    choice = registry.select_attn(phase=Phase.DECODE, s=512, table_path=path)
    assert choice.backend == "pallas" and choice.blocks == (1, 64)


def test_attn_kv_key_quarantine_is_per_layout(tmp_path):
    """Demoting the kv8 decode key must not quarantine the bf16 path (and
    vice versa): a kernel failing on int8 pages stays available for raw
    bf16 serving."""
    empty = str(tmp_path / "empty.json")
    registry.save_table({"entries": {}}, empty)
    key8 = registry.attn_dispatch_key(Phase.DECODE, 512, "tpu-v5e", kv="kv8")
    before = registry.resolve_key(key8, table_path=empty)
    assert before.backend == "pallas"
    record = registry.demote(key8, failing="pallas", reason="test")
    try:
        assert record["to"] == "xla"
        after = registry.resolve_key(key8, table_path=empty)
        assert after.backend == "xla"
        bf16 = registry.resolve_key(
            registry.attn_dispatch_key(Phase.DECODE, 512, "tpu-v5e"),
            table_path=empty,
        )
        assert bf16.backend == "pallas"  # untouched
    finally:
        registry.clear_quarantine()


def test_attn_checked_in_table_covers_serving_buckets():
    """The committed table carries tuned attn entries for the decode and
    prefill serving regimes (kernel_bench --tune-attn writes them)."""
    for phase in (Phase.DECODE, Phase.PREFILL):
        for s in (256, 768, 2048):
            choice = registry.select_attn(phase=phase, s=s)
            assert choice.source == "tuned", (phase, s)
            assert choice.backend in registry.ATTN_BACKENDS
