"""End-to-end behaviour tests: the shipped drivers run and do what they say."""

import sys

import numpy as np


def test_train_driver_end_to_end(monkeypatch, tmp_path):
    from repro.launch import train as train_main

    argv = [
        "train", "--arch", "qwen2-1.5b", "--steps", "8", "--batch", "4",
        "--seq", "32", "--lr", "3e-3", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "4", "--log-every", "4",
    ]
    monkeypatch.setattr(sys, "argv", argv)
    losses = train_main.main()
    assert len(losses) == 8
    assert all(np.isfinite(l) for l in losses)
    # checkpoints written
    from repro.checkpoint import checkpoint as ckpt_lib
    assert ckpt_lib.latest_step(str(tmp_path)) == 8

    # resume pass: picks up from step 8 and runs to 10
    argv2 = argv[:]
    argv2[argv2.index("--steps") + 1] = "10"
    monkeypatch.setattr(sys, "argv", argv2)
    losses2 = train_main.main()
    assert len(losses2) == 2


def test_serve_driver_end_to_end(monkeypatch):
    from repro.launch import serve as serve_main

    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", "llama3.2-1b", "--requests", "4",
        "--slots", "2", "--max-new", "4", "--prompt-len", "8",
    ])
    done = serve_main.main()
    assert len(done) == 4
    assert all(len(r.generated) == 4 for r in done)


def test_dryrun_registry_covers_40_cells():
    from repro.configs import registry

    cells = registry.all_cells()
    assert len(cells) == 40
    assert sum(1 for _, _, ok, _ in cells if ok) == 33
