"""Attention microkernels (kernels/attn.py) vs the jnp references: randomized
parity across GQA ratios, ragged per-row positions, ring windows, the L > 1
spec-decode verify window, paged-vs-dense bit-consistency, and the
attention_apply / engine routing through registry.select_attn."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry as cfg_registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.kernels import attn as attn_lib
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving import engine as engine_lib

TOL = dict(rtol=2e-5, atol=2e-5)


def _rand(rng, *shape):
    return jnp.asarray(rng.randn(*shape), jnp.float32)


# ---------------------------------------------------------------------------
# Dense decode kernel


@pytest.mark.parametrize("h,kv", [(4, 4), (8, 2), (8, 1)])  # G = 1, 4, 8
def test_dense_decode_parity_gqa_ragged_pos(h, kv):
    """Kernel == attention_decode across GQA ratios with every batch row at
    its own position (position-vectorized decode), ragged S vs kv_chunk."""
    rng = np.random.RandomState(0)
    b, d, s = 3, 16, 37
    q = _rand(rng, b, 1, h, d)
    k = _rand(rng, b, s, kv, d)
    v = _rand(rng, b, s, kv, d)
    pos = jnp.asarray(rng.randint(0, s, b), jnp.int32)
    want = L.attention_decode(q, k, v, pos=pos, window=0)
    got = attn_lib.dense_decode_attention(
        q, k, v, pos, window=0, kv_chunk=8, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_dense_decode_shared_scalar_pos():
    rng = np.random.RandomState(1)
    b, h, kv, d, s = 2, 4, 2, 8, 24
    q = _rand(rng, b, 1, h, d)
    k = _rand(rng, b, s, kv, d)
    v = _rand(rng, b, s, kv, d)
    want = L.attention_decode(q, k, v, pos=11, window=0)
    got = attn_lib.dense_decode_attention(
        q, k, v, jnp.asarray(11, jnp.int32), window=0, kv_chunk=8,
        interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_dense_decode_verify_window_matches_reference_and_sequential():
    """L > 1 (spec-decode verify): the kernel's masked-causal window equals
    the reference AND L sequential one-token kernel decodes (query j sees
    exactly the history plus drafts 0..j)."""
    rng = np.random.RandomState(2)
    b, Lq, h, kv, d, s = 2, 3, 8, 2, 16, 32
    q = _rand(rng, b, Lq, h, d)
    k = _rand(rng, b, s, kv, d)
    v = _rand(rng, b, s, kv, d)
    pos = jnp.asarray([5, 20], jnp.int32)
    want = L.attention_decode(q, k, v, pos=pos, window=0)
    got = attn_lib.dense_decode_attention(
        q, k, v, pos, window=0, kv_chunk=8, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    # Sequential equivalence is BITWISE: the j-th window query and a lone
    # one-token decode at pos+j share chunk boundaries, and chunks masked
    # for query j are exact no-ops of the online accumulator.
    for j in range(Lq):
        lone = attn_lib.dense_decode_attention(
            q[:, j : j + 1], k, v, pos + j, window=0, kv_chunk=8,
            interpret=True,
        )
        np.testing.assert_array_equal(
            np.asarray(got[:, j : j + 1]), np.asarray(lone)
        )


@pytest.mark.parametrize("positions", [[3, 7], [15, 29], [12, 40]])
def test_dense_decode_ring_window_parity(positions):
    """Sliding-window ring cache: fresh rows (qpos < window) and wrapped rows
    (qpos >= S_c) both match the reference ring-age mask."""
    rng = np.random.RandomState(3)
    b, h, kv, d, w = 2, 4, 2, 8, 12
    s = w  # ring cache holds exactly `window` slots
    q = _rand(rng, b, 1, h, d)
    k = _rand(rng, b, s, kv, d)
    v = _rand(rng, b, s, kv, d)
    pos = jnp.asarray(positions, jnp.int32)
    want = L.attention_decode(q, k, v, pos=pos, window=w)
    got = attn_lib.dense_decode_attention(
        q, k, v, pos, window=w, kv_chunk=4, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_window_mask_cheap_prefix_equivalence():
    """Satellite: for rows with qpos < window the ring-age mask must reduce
    to the cheap `slot <= qpos` prefix mask — pin the equivalence by
    comparing the windowed reference against the full-attention reference
    while the window has not filled."""
    rng = np.random.RandomState(4)
    b, h, kv, d, w = 2, 4, 2, 8, 16
    s = w
    q = _rand(rng, b, 1, h, d)
    k = _rand(rng, b, s, kv, d)
    v = _rand(rng, b, s, kv, d)
    pos = jnp.asarray([2, 9], jnp.int32)  # both < window
    windowed = L.attention_decode(q, k, v, pos=pos, window=w)
    full = L.attention_decode(q, k, v, pos=pos, window=0)
    np.testing.assert_array_equal(np.asarray(windowed), np.asarray(full))


def test_masked_softmax_all_masked_rows_are_zero_not_nan():
    """Satellite: a fully-masked row (padded admission slot) must come back
    all-zero — never NaN — from the guarded softmax."""
    s = jnp.asarray([[1.0, 2.0, 3.0], [5.0, -1.0, 0.5]], jnp.float32)
    valid = jnp.asarray([[False, False, False], [True, False, True]])
    p = L._masked_softmax(s, valid)
    assert bool(jnp.all(jnp.isfinite(p)))
    np.testing.assert_array_equal(np.asarray(p[0]), np.zeros(3, np.float32))
    np.testing.assert_allclose(float(p[1].sum()), 1.0, rtol=1e-6)
    assert float(p[1, 1]) == 0.0


def test_masked_positions_never_leak_garbage():
    """Poisoned K/V at masked positions (stale drafts, uninitialized pages)
    must not perturb kernel or reference output."""
    rng = np.random.RandomState(5)
    b, h, kv, d, s = 2, 4, 2, 8, 24
    q = _rand(rng, b, 1, h, d)
    k = _rand(rng, b, s, kv, d)
    v = _rand(rng, b, s, kv, d)
    pos = jnp.asarray([7, 15], jnp.int32)
    clean_ref = L.attention_decode(q, k, v, pos=pos, window=0)
    clean_ker = attn_lib.dense_decode_attention(
        q, k, v, pos, window=0, kv_chunk=8, interpret=True
    )
    big = 1e30
    k_poison = k.at[0, 8:].set(big).at[1, 16:].set(-big)
    v_poison = v.at[0, 8:].set(-big).at[1, 16:].set(big)
    np.testing.assert_array_equal(
        np.asarray(L.attention_decode(q, k_poison, v_poison, pos=pos, window=0)),
        np.asarray(clean_ref),
    )
    np.testing.assert_array_equal(
        np.asarray(attn_lib.dense_decode_attention(
            q, k_poison, v_poison, pos, window=0, kv_chunk=8, interpret=True
        )),
        np.asarray(clean_ker),
    )


# ---------------------------------------------------------------------------
# Paged decode kernel


def _paged_case(rng, b, nb, bs, kv, d, h, Lq, share=False):
    pool_k = _rand(rng, 1 + b * nb, bs, kv, d)
    pool_v = _rand(rng, 1 + b * nb, bs, kv, d)
    table = (1 + rng.permutation(b * nb).reshape(b, nb)).astype(np.int32)
    if share and b > 1:
        table[1, 0] = table[0, 0]  # prefix-reuse: two slots share a page
    table = jnp.asarray(table)
    q = _rand(rng, b, Lq, h, d)
    pos = jnp.asarray(rng.randint(0, nb * bs - Lq + 1, b), jnp.int32)
    return q, pool_k, pool_v, table, pos


@pytest.mark.parametrize("share", [False, True])
@pytest.mark.parametrize("Lq", [1, 3])
def test_paged_decode_parity_vs_gather_reference(share, Lq):
    """In-kernel block-table gather == paged_gather + attention_decode, for
    arbitrary tables (including shared prefix pages) and verify windows."""
    rng = np.random.RandomState(6)
    b, nb, bs, kv, d, h = 3, 5, 8, 2, 16, 8
    q, pool_k, pool_v, table, pos = _paged_case(rng, b, nb, bs, kv, d, h, Lq, share)
    want = L.attention_decode(
        q, L.paged_gather(pool_k, table), L.paged_gather(pool_v, table),
        pos=pos, window=0,
    )
    got = attn_lib.paged_decode_attention(
        q, pool_k, pool_v, table, pos, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_paged_vs_dense_kernel_bit_consistency():
    """At matched streaming granularity (dense kv_chunk == page block size)
    the paged kernel and the dense kernel on the gathered view are BITWISE
    identical — the in-kernel gather changes where bytes come from, never
    a single float op."""
    rng = np.random.RandomState(7)
    b, nb, bs, kv, d, h, Lq = 3, 4, 8, 2, 16, 8, 2
    q, pool_k, pool_v, table, pos = _paged_case(rng, b, nb, bs, kv, d, h, Lq)
    paged = attn_lib.paged_decode_attention(
        q, pool_k, pool_v, table, pos, interpret=True
    )
    dense = attn_lib.dense_decode_attention(
        q, L.paged_gather(pool_k, table), L.paged_gather(pool_v, table),
        pos, window=0, kv_chunk=bs, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))


def test_paged_gather_nb_blocks_bound():
    """Satellite: the bounded fallback gather returns exactly the leading
    slice of the full gather."""
    rng = np.random.RandomState(8)
    pool = _rand(rng, 9, 4, 2, 8)
    table = jnp.asarray(1 + rng.permutation(8).reshape(2, 4), jnp.int32)
    full = L.paged_gather(pool, table)
    for nb in (1, 2, 4, 7):
        got = L.paged_gather(pool, table, nb_blocks=nb)
        eff = min(nb, 4)
        assert got.shape[1] == eff * 4
        np.testing.assert_array_equal(np.asarray(got), np.asarray(full[:, : eff * 4]))


# ---------------------------------------------------------------------------
# Flash prefill kernel


@pytest.mark.parametrize("h,kv", [(4, 4), (8, 2), (8, 1)])
def test_flash_prefill_parity_gqa(h, kv):
    rng = np.random.RandomState(9)
    b, sq, d = 2, 33, 16
    q = _rand(rng, b, sq, h, d)
    k = _rand(rng, b, sq, kv, d)
    v = _rand(rng, b, sq, kv, d)
    want = L.attention_chunked(
        q, k, v, causal=True, window=0, q_chunk=8, kv_chunk=8
    )
    got = attn_lib.flash_prefill_attention(
        q, k, v, causal=True, q_chunk=8, kv_chunk=8, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("window,q_offset,causal", [
    (7, 0, True),    # sliding-window prefill
    (0, 8, True),    # chunked prefill: q at an absolute offset into the cache
    (0, 0, False),   # bidirectional (encoder)
])
def test_flash_prefill_parity_modes(window, q_offset, causal):
    rng = np.random.RandomState(10)
    b, sq, h, kv, d = 2, 19, 4, 2, 8
    sk = sq + q_offset
    q = _rand(rng, b, sq, h, d)
    k = _rand(rng, b, sk, kv, d)
    v = _rand(rng, b, sk, kv, d)
    want = L.attention_chunked(
        q, k, v, causal=causal, window=window, q_chunk=8, kv_chunk=8,
        q_offset=q_offset,
    )
    got = attn_lib.flash_prefill_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        q_chunk=8, kv_chunk=8, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# ---------------------------------------------------------------------------
# attention_apply routing (registry.select_attn) and engine integration


@pytest.fixture(scope="module")
def small_model():
    cfg = cfg_registry.get_reduced("qwen2-1.5b")
    enc = EncodingConfig(enabled=True, backend="xla", attn_backend="xla")
    params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
    return cfg, params


def _forward_logits(cfg, params, enc, tokens, phase, caches, pos=0):
    logits, caches, _ = T.forward(
        params, {"tokens": tokens}, cfg=cfg, enc=enc, phase=phase,
        caches=caches, pos=pos,
    )
    return logits, caches


def test_attention_apply_backends_agree_end_to_end(small_model):
    """Full forward (prefill then vectorized decode) with attn_backend
    "pallas" stays within fp tolerance of "xla" and picks the same argmax."""
    cfg, params = small_model
    rng = np.random.RandomState(11)
    toks = jnp.asarray(rng.randint(1, cfg.vocab_size, (2, 9)), jnp.int32)
    outs = {}
    for be in ("xla", "pallas", "auto"):
        enc = EncodingConfig(enabled=True, backend="xla", attn_backend=be)
        caches = T.cache_init(cfg, 2, max_seq=16)
        lp, caches = _forward_logits(cfg, params, enc, toks, Phase.PREFILL, caches)
        nxt = jnp.argmax(lp[:, -1], -1).astype(jnp.int32)[:, None]
        ld, _ = _forward_logits(
            cfg, params, enc, nxt, Phase.DECODE, caches,
            pos=jnp.asarray([9, 9], jnp.int32),
        )
        outs[be] = (np.asarray(lp[:, -1]), np.asarray(ld[:, -1]))
    for be in ("pallas", "auto"):
        for a, b in zip(outs["xla"], outs[be]):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
            np.testing.assert_array_equal(a.argmax(-1), b.argmax(-1))
    # auto resolves to the pallas kernels (tuned/default), bitwise equal.
    for a, b in zip(outs["pallas"], outs["auto"]):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("cache_mode", ["paged", "dense"])
def test_engine_tokens_identical_across_attn_backends(small_model, cache_mode):
    """Serving engines emit identical tokens whichever attention backend
    serves them (paged: the in-kernel gather path; dense: the chunked
    kernel), under skewed prompts and multi-wave admission."""
    cfg, params = small_model
    rng = np.random.RandomState(12)
    prompts = [rng.randint(1, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 7, 5, 9)]
    got = {}
    for be in ("xla", "pallas"):
        enc = EncodingConfig(enabled=True, backend="xla", attn_backend=be)
        eng = engine_lib.Engine(
            params, cfg, enc, slots=2, max_seq=32, cache_mode=cache_mode
        )
        for i, p in enumerate(prompts):
            eng.submit(engine_lib.Request(uid=i, prompt=p, max_new_tokens=6))
        done = eng.run()
        eng.audit()
        got[be] = {r.uid: r.generated for r in done}
        assert eng.stats["attn_backend"] == be
    assert got["xla"] == got["pallas"]


def test_engine_spec_decode_on_pallas_attention(small_model):
    """Speculative decode (L > 1 verify window) rides the paged kernel:
    token-identical to the plain engine on the same backend."""
    cfg, params = small_model
    rng = np.random.RandomState(13)
    phrase = rng.randint(1, cfg.vocab_size, 4).astype(np.int32)
    prompt = np.tile(phrase, 4)
    enc = EncodingConfig(enabled=True, backend="xla", attn_backend="pallas")
    gens = {}
    for spec in (False, True):
        eng = engine_lib.Engine(
            params, cfg, enc, slots=1, max_seq=64, spec_decode=spec, draft_k=4
        )
        eng.submit(engine_lib.Request(uid=0, prompt=prompt, max_new_tokens=16))
        done = eng.run()
        gens[spec] = done[0].generated
    assert gens[True] == gens[False]


def test_engine_live_table_width_is_bounded(small_model):
    """Satellite: the table leaf threaded into the decode dispatch covers
    only the live page bucket, not the full block-table width."""
    cfg, params = small_model
    enc = EncodingConfig(enabled=True, backend="xla", attn_backend="pallas")
    eng = engine_lib.Engine(
        params, cfg, enc, slots=2, max_seq=128, cache_mode="paged",
        block_size=8,
    )
    assert eng.cache_mode == "paged"
    rng = np.random.RandomState(14)
    eng.submit(engine_lib.Request(
        uid=0, prompt=rng.randint(1, cfg.vocab_size, 5).astype(np.int32),
        max_new_tokens=4,
    ))
    eng.step()
    width = eng._live_table_width()
    assert width == 1  # 5 prompt + first tokens -> one 8-token page
    assert width < eng.num_blocks
    tables = [leaf for path, leaf in
              jax.tree_util.tree_flatten_with_path(eng.caches)[0]
              if "table" in jax.tree_util.keystr(path)]
    assert tables and all(t.shape[-1] == width for t in tables)
    eng.run()
    eng.audit()
