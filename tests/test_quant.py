"""int8 serving path (w8a8, kernels/mmt4d_q8.py): kernel vs oracle, quality
vs the bf16/f32 path, model-level argmax preservation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.kernels import ops, ref
from repro.models import transformer as T


@pytest.mark.parametrize("mnk", [(8, 64, 32), (1, 256, 128), (130, 140, 150)])
def test_q8_kernel_matches_oracle(mnk):
    m, n, k = mnk
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
    rhs4_q, s_w = ops.pack_rhs_q8(w_t)
    got_x = ops.encoded_matmul_q8(
        x, rhs4_q, s_w, n=n, phase=Phase.DECODE, backend="xla", out_dtype=jnp.float32
    )
    got_p = ops.encoded_matmul_q8(
        x, rhs4_q, s_w, n=n, phase=Phase.DECODE, backend="pallas",
        out_dtype=jnp.float32, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(got_p), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mnk", [(4, 128, 256), (64, 512, 384)])
def test_q8_close_to_full_precision(mnk):
    m, n, k = mnk
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
    exact = ref.matmul_reference(x, w_t)
    rhs4_q, s_w = ops.pack_rhs_q8(w_t)
    q8 = ops.encoded_matmul_q8(
        x, rhs4_q, s_w, n=n, phase=Phase.PREFILL, backend="xla", out_dtype=jnp.float32
    )
    rel = float(jnp.linalg.norm(q8 - exact) / jnp.linalg.norm(exact))
    assert rel < 0.02, rel  # w8a8 with per-channel/per-row scales


def test_quantize_rows_bounds():
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(32, 64) * 7, jnp.float32)
    q, s = ref.quantize_rows(x)
    assert q.dtype == jnp.int8
    err = jnp.abs(q.astype(jnp.float32) * s[:, None] - x)
    assert float(err.max()) <= float(s.max()) / 2 + 1e-6


def test_model_level_int8_serving_argmax():
    """Quantized serving model preserves the full-precision model's decisions
    (the Table-1 bar, stated at int8 granularity).

    Argmax equality over ALL positions is not a property w8a8 can provide: a
    random-init reduced model produces near-tied top-2 logits (margins ~10x
    below the median) at a few positions, where any rounding flips the pick.
    The meaningful model-level claims, asserted here:
      * logits stay close in norm (MSE-clip weight quant: rel < 0.03, was
        ~0.1 under absmax — the bound is tightened accordingly),
      * argmax agrees at the vast majority of positions,
      * bounded regret everywhere: where the pick differs, the quantized
        choice's full-precision logit is within a small fraction of the
        median top-2 margin of the optimum — flips happen only at
        near-ties, never a materially worse token, and
      * the w8a8 path holds END-TO-END at the model level: greedy decode
        through the serving cache path emits exactly the tokens the same
        quantized model picks with full-context prefill (prefill/decode
        continuity of the quantized serving path itself)."""
    cfg = registry.get_reduced("llama3.2-1b")
    enc_fp = EncodingConfig(enabled=True, backend="xla")
    enc_q8 = EncodingConfig(enabled=True, backend="xla", weight_quant="int8")
    p_fp = T.model_init(jax.random.PRNGKey(0), cfg, enc_fp)
    p_q8 = T.model_init(jax.random.PRNGKey(0), cfg, enc_q8)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 1, cfg.vocab_size)
    l_fp, _, _ = T.forward(p_fp, {"tokens": toks}, cfg=cfg, enc=enc_fp, phase=Phase.PREFILL)
    l_q8, _, _ = T.forward(p_q8, {"tokens": toks}, cfg=cfg, enc=enc_q8, phase=Phase.PREFILL)
    rel = float(jnp.linalg.norm(l_q8 - l_fp) / jnp.linalg.norm(l_fp))
    assert rel < 0.03, rel
    am_fp = jnp.argmax(l_fp, -1)
    am_q8 = jnp.argmax(l_q8, -1)
    agree = float(jnp.mean(am_fp == am_q8))
    assert agree > 0.8, agree
    top2 = jax.lax.top_k(l_fp, 2)[0]
    median_margin = float(jnp.median(top2[..., 0] - top2[..., 1]))
    # Regret of the quantized pick, measured in full-precision logits.
    l_of_q8 = jnp.take_along_axis(l_fp, am_q8[..., None], axis=-1)[..., 0]
    l_of_fp = jnp.take_along_axis(l_fp, am_fp[..., None], axis=-1)[..., 0]
    regret = float(jnp.max(l_of_fp - l_of_q8))
    assert regret < 0.25 * median_margin, (regret, median_margin)

    # End-to-end w8a8 serving: prefill 8 tokens into the cache, greedy-decode
    # 4 more; each decoded argmax must equal the quantized model's own
    # full-context prefill argmax at that position.
    sp, b, s = 8, *toks.shape
    caches = T.cache_init(cfg, b, max_seq=s)
    _, caches, _ = T.forward(
        p_q8, {"tokens": toks[:, :sp]}, cfg=cfg, enc=enc_q8,
        phase=Phase.PREFILL, caches=caches,
    )
    for i in range(sp, s):
        l_d, caches, _ = T.forward(
            p_q8, {"tokens": toks[:, i : i + 1]}, cfg=cfg, enc=enc_q8,
            phase=Phase.DECODE, caches=caches, pos=i,
        )
        assert bool((jnp.argmax(l_d[:, 0], -1) == jnp.argmax(l_q8[:, i], -1)).all()), i
