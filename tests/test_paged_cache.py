"""Paged KV-cache allocator invariants (serving/paged.py) and the paged
engine's page accounting: no double allocation, exact freed-on-finish
refcounts, copy-on-write only at the first divergent block, preempted
requests finishing with correct tokens."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import encoding
from repro.core.packed import EncodingConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving import engine as engine_lib
from repro.serving import paged as paged_lib

ENC = EncodingConfig(enabled=True, backend="xla")


# ---------------------------------------------------------------------------
# Pure allocator


def test_allocator_no_double_allocation_fuzz():
    """Random alloc/free interleavings: a page is never handed out twice,
    and free + in-use always partitions the pool exactly."""
    rng = np.random.RandomState(0)
    alloc = paged_lib.BlockAllocator(num_pages=9, block_size=4)
    held: list[int] = []
    for _ in range(500):
        if held and (rng.rand() < 0.45 or not alloc.available()):
            alloc.free_page(held.pop(rng.randint(len(held))))
        else:
            page = alloc.alloc()
            if page is None:
                assert alloc.available() == 0
                continue
            assert page not in held, "double-allocated page"
            assert page != paged_lib.SCRATCH_PAGE
            held.append(page)
        alloc.audit([held])
    for p in list(held):
        alloc.free_page(p)
    alloc.audit([])
    assert alloc.available() == alloc.capacity


def test_allocator_prefix_share_and_cow_first_divergence():
    """Two prompts sharing exactly two full blocks: the leading two pages are
    refcount-shared, copy-on-write triggers exactly once — at the first
    divergent block — and every later block allocates privately."""
    bs = 4
    alloc = paged_lib.BlockAllocator(num_pages=17, block_size=bs)
    a = np.arange(1, 14, dtype=np.int32)           # 13 tokens: 4 blocks
    nb_a, shared_a = alloc.plan_prompt(a)
    assert (nb_a, shared_a) == (4, {})             # empty registry: no reuse
    plan_a = alloc.commit_prompt(a, nb_a, shared_a)
    assert plan_a.shared == [False] * 4
    assert alloc.stats["cow_events"] == 0

    b = a.copy()
    b[2 * bs] += 1                                  # diverge at block 2
    nb_b, shared_b = alloc.plan_prompt(b)
    assert nb_b == 4 and set(shared_b) == {0, 1}    # blocks 0,1 reusable
    assert [shared_b[j] for j in (0, 1)] == plan_a.pages[:2]
    plan_b = alloc.commit_prompt(b, nb_b, shared_b)
    assert plan_b.shared == [True, True, False, False]
    assert plan_b.pages[:2] == plan_a.pages[:2]
    assert not set(plan_b.pages[2:]) & set(plan_a.pages), "divergent blocks share"
    assert alloc.stats["cow_events"] == 1           # exactly one CoW point
    assert alloc.refcount[plan_a.pages[0]] == 2
    alloc.audit([plan_a.pages, plan_b.pages])

    # A prompt divergent from block 0 shares nothing and triggers no CoW.
    c = a.copy()
    c[0] += 1
    nb_c, shared_c = alloc.plan_prompt(c)
    assert shared_c == {}
    alloc.commit_prompt(c, nb_c, shared_c)
    assert alloc.stats["cow_events"] == 1


def test_allocator_partial_last_block_never_shared():
    """The block holding position plen-1 is appendable (decode rewrites it),
    so it must never enter the prefix registry."""
    bs = 4
    alloc = paged_lib.BlockAllocator(num_pages=9, block_size=bs)
    a = np.arange(1, 9, dtype=np.int32)    # 8 tokens: blocks 0,1 full
    nb_a, shared_a = alloc.plan_prompt(a)
    plan_a = alloc.commit_prompt(a, nb_a, shared_a)
    assert plan_a is not None
    # shareable = (8-1)//4 = 1: only block 0 registered, block 1 appendable.
    nb, shared = alloc.plan_prompt(a.copy())
    assert set(shared) == {0}


def test_allocator_commit_rolls_back_when_pool_dry():
    alloc = paged_lib.BlockAllocator(num_pages=3, block_size=4)  # capacity 2
    long = np.arange(1, 14, dtype=np.int32)  # needs 4 blocks
    nb, shared = alloc.plan_prompt(long)
    assert alloc.commit_prompt(long, nb, shared) is None
    alloc.audit([])                           # rollback left nothing behind
    assert alloc.available() == alloc.capacity


# ---------------------------------------------------------------------------
# Engine-level accounting


def _drain(eng, *, audit=True):
    steps = 0
    while eng.queue or any(r is not None for r in eng.slot_req):
        eng.step()
        if audit:
            eng.audit()
        steps += 1
        assert steps < 1000
    return {r.uid: r.generated for r in eng.finished}


def test_engine_freed_on_finish_exact():
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    eng = engine_lib.Engine(
        params, cfg, ENC, slots=3, max_seq=32, cache_mode="paged", block_size=4
    )
    rng = np.random.RandomState(3)
    for i in range(6):
        eng.submit(engine_lib.Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, rng.randint(2, 10)).astype(np.int32),
            max_new_tokens=int(rng.randint(1, 7)),
        ))
    done = _drain(eng)
    assert len(done) == 6
    stats = eng.stats
    assert stats["pages_in_use"] == 0
    assert stats["pages_free"] == stats["pages_total"]
    assert stats["allocs"] == stats["frees"]          # every page returned once
    assert all(int(p) == paged_lib.SCRATCH_PAGE for p in eng.block_table.ravel())


def test_engine_preempted_requests_finish_with_correct_tokens():
    """A pool too small for concurrent growth forces eviction + replay; the
    preempted requests must still produce exactly the dense engine's tokens."""
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    rng = np.random.RandomState(4)
    reqs = [
        engine_lib.Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, 5 + i).astype(np.int32),
            max_new_tokens=8,
        )
        for i in range(3)
    ]
    import dataclasses
    eng_d = engine_lib.Engine(params, cfg, ENC, slots=3, max_seq=32, cache_mode="dense")
    for r in reqs:
        eng_d.submit(dataclasses.replace(r, generated=[]))
    want = _drain(eng_d, audit=False)

    eng_p = engine_lib.Engine(
        params, cfg, ENC, slots=3, max_seq=32, cache_mode="paged",
        block_size=4, pool_pages=5,   # capacity 4 = one request's worst case
    )
    for r in reqs:
        eng_p.submit(dataclasses.replace(r, generated=[]))
    got = _drain(eng_p)
    assert eng_p.stats["preemptions"] > 0, eng_p.stats
    assert got == want


def test_engine_rejects_unserviceable_request():
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    eng = engine_lib.Engine(
        params, cfg, ENC, slots=2, max_seq=64, cache_mode="paged",
        block_size=4, pool_pages=4,
    )
    # Rejected at submit, before any page could be committed: a half-admitted
    # batch must never be abandoned mid-flight.  Structured backpressure:
    # submit returns a falsy Rejected(reason) rather than raising.
    res = eng.submit(engine_lib.Request(
        uid=0, prompt=np.arange(1, 30, dtype=np.int32), max_new_tokens=8,
    ))
    assert not res
    assert isinstance(res, engine_lib.Rejected)
    assert res.reason == "unserviceable_pool"
    assert "pool" in res.detail
    eng.audit()
    assert eng.alloc.available() == eng.alloc.capacity
    assert not eng.queue and eng.rejected[0].status == "rejected"


def test_engine_tenant_quota_fairness_under_flood():
    """One tenant floods the queue; with tenant_quota set, its worst-case
    reservations are capped so the other tenant is admitted alongside it
    (quota-blocked requests are SKIPPED, not head-of-line blockers), no
    tenant's reserved or charged pages ever exceed the quota, and a request
    whose own worst case outgrows the quota is rejected at submit."""
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    quota = 6
    eng = engine_lib.Engine(
        params, cfg, ENC, slots=3, max_seq=32, cache_mode="paged",
        block_size=4, pool_pages=25, tenant_quota=quota,
    )
    rng = np.random.RandomState(7)
    # Each request's worst case is min(6+6, 32)-1 = pos 11 -> 3 pages, so the
    # quota admits at most two per tenant concurrently.  t0 floods first.
    uid = 0
    for tenant, n in (("t0", 5), ("t1", 2)):
        for _ in range(n):
            assert eng.submit(engine_lib.Request(
                uid=uid, tenant=tenant,
                prompt=rng.randint(1, cfg.vocab_size, 6).astype(np.int32),
                max_new_tokens=6,
            ))
            uid += 1
    saw_fair = False
    steps = 0
    while eng.queue or any(r is not None for r in eng.slot_req):
        eng.step()
        eng.audit()
        for pages in eng._tenant_reserved.values():
            assert pages <= quota
        for u in eng.alloc.tenant_usage().values():
            assert u <= quota + 1e-9
        running = {r.tenant for r in eng.slot_req if r is not None}
        if "t1" in running and any(r.tenant == "t0" for r in eng.queue):
            saw_fair = True        # t1 runs while t0 still has queued work
    assert saw_fair
    assert len(eng.finished) == 7 and not eng._tenant_reserved
    assert eng.stats["prefix_cache"]["tenant_quota"] == quota

    # Worst case 7 pages > quota 6 (but < pool): rejected up front rather
    # than queued to starve behind an admission gate it can never pass.
    res = eng.submit(engine_lib.Request(
        uid=99, tenant="t0", prompt=np.arange(1, 18, dtype=np.int32),
        max_new_tokens=8,
    ))
    assert isinstance(res, engine_lib.Rejected)
    assert res.reason == "unserviceable_quota"


# ---------------------------------------------------------------------------
# Gather correctness + capacity math (non-hypothesis seeds; the hypothesis
# sweep lives in tests/test_paged_property.py)


def test_paged_gather_matches_dense_slice_seeded():
    rng = np.random.RandomState(5)
    b, nb, bs, kv, hd = 3, 4, 4, 2, 6
    dense = rng.randn(b, nb * bs, kv, hd).astype(np.float32)
    pool = np.zeros((1 + b * nb, bs, kv, hd), np.float32)
    table = np.zeros((b, nb), np.int32)
    page = 1
    for i in range(b):
        for j in range(nb):
            pool[page] = dense[i, j * bs : (j + 1) * bs]
            table[i, j] = page
            page += 1
    got = L.paged_gather(jnp.asarray(pool), jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(got), dense)


def test_kv_capacity_math():
    cap = encoding.kv_capacity_requests(
        hbm_budget=16 * (1 << 20), max_seq=2048, mean_tokens=256,
        block_size=16, num_layers=16, num_kv_heads=2, head_dim=64,
    )
    # 256-token requests against a 2048-token worst case: 8x the requests.
    assert cap["paged"] == 8 * cap["dense"]
    assert cap["bytes_per_token"] == 2 * 16 * 2 * 64 * 2
