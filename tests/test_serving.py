"""Serving invariants: prefill->decode continuity per family, engine
continuous batching, parity of encoded vs reference model (Table-1 analog)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.serving import engine as engine_lib

ENC = EncodingConfig(enabled=True, backend="xla")


def _continuity(arch, tol, **cfg_over):
    cfg = registry.get_reduced(arch, **cfg_over)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 1, cfg.vocab_size)
    full = {"tokens": toks}
    pfx = 0  # logits offset for multimodal prefixes
    if cfg.family == "encdec":
        full["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.frontend_tokens, cfg.d_model)
        )
    if cfg.family == "vlm":
        full["patches"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.frontend_tokens, cfg.frontend_dim)
        )
        pfx = cfg.frontend_tokens
    logits_full, _, _ = T.forward(params, full, cfg=cfg, enc=ENC, phase=Phase.PREFILL)

    sp = s - 4
    caches = T.cache_init(cfg, b, max_seq=s + pfx)
    part = dict(full)
    part["tokens"] = toks[:, :sp]
    logits_p, caches, _ = T.forward(
        params, part, cfg=cfg, enc=ENC, phase=Phase.PREFILL, caches=caches
    )
    errs = [float(jnp.max(jnp.abs(logits_p - logits_full[:, : pfx + sp])))]
    for i in range(sp, s):
        logits_d, caches, _ = T.forward(
            params, {"tokens": toks[:, i : i + 1]},
            cfg=cfg, enc=ENC, phase=Phase.DECODE, caches=caches, pos=pfx + i,
        )
        errs.append(float(jnp.max(jnp.abs(logits_d[:, 0] - logits_full[:, pfx + i]))))
    assert max(errs) < tol, f"{arch}: prefill/decode diverge: {errs}"


@pytest.mark.parametrize("arch,tol", [
    ("qwen2-1.5b", 1e-4),
    ("yi-9b", 1e-4),
    ("rwkv6-1.6b", 1e-4),
    ("recurrentgemma-9b", 1e-4),
    ("whisper-tiny", 1e-4),
    ("internvl2-26b", 1e-4),
])
def test_prefill_decode_continuity(arch, tol):
    _continuity(arch, tol)


def test_moe_continuity_with_unbounded_capacity():
    """Capacity-based token dropping is batch-dependent (expected divergence);
    with non-binding capacity the MoE path must be exactly continuous too."""
    _continuity("mixtral-8x22b", 1e-4, capacity_factor=8.0)


def test_sliding_window_ring_buffer():
    """Decode beyond the window: ring-buffer cache == full-cache windowed attn."""
    cfg = registry.get_reduced("mixtral-8x22b", capacity_factor=8.0, sliding_window=6)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    b, s = 1, 14
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 1, cfg.vocab_size)
    logits_full, _, _ = T.forward(
        params, {"tokens": toks}, cfg=cfg, enc=ENC, phase=Phase.PREFILL
    )
    sp = 4  # prefill less than the window, then decode far past it
    caches = T.cache_init(cfg, b, max_seq=s)
    _, caches, _ = T.forward(
        params, {"tokens": toks[:, :sp]}, cfg=cfg, enc=ENC,
        phase=Phase.PREFILL, caches=caches,
    )
    errs = []
    for i in range(sp, s):
        logits_d, caches, _ = T.forward(
            params, {"tokens": toks[:, i : i + 1]},
            cfg=cfg, enc=ENC, phase=Phase.DECODE, caches=caches, pos=i,
        )
        errs.append(float(jnp.max(jnp.abs(logits_d[:, 0] - logits_full[:, i]))))
    assert max(errs) < 1e-4, errs


def test_engine_continuous_batching():
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    eng = engine_lib.Engine(params, cfg, ENC, slots=2, max_seq=48)
    rng = np.random.RandomState(0)
    for i in range(5):
        plen = rng.randint(3, 9)
        eng.submit(engine_lib.Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=6,
        ))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.generated) == 6 for r in done)


def test_engine_matches_sequential_decode():
    """Engine output == naive one-request-at-a-time decode (greedy)."""
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab_size, rng.randint(3, 7)).astype(np.int32)
               for _ in range(3)]

    eng = engine_lib.Engine(params, cfg, ENC, slots=2, max_seq=32)
    for i, p in enumerate(prompts):
        eng.submit(engine_lib.Request(uid=i, prompt=p, max_new_tokens=5))
    got = {r.uid: r.generated for r in eng.run()}

    for i, p in enumerate(prompts):
        caches = T.cache_init(cfg, 1, max_seq=32)
        logits, caches, _ = T.forward(
            params, {"tokens": jnp.asarray(p)[None]},
            cfg=cfg, enc=ENC, phase=Phase.PREFILL, caches=caches,
        )
        toks = []
        last = int(p[-1])
        pos = len(p) - 1
        for _ in range(5):
            logits, caches, _ = T.forward(
                params, {"tokens": jnp.asarray([[last]], jnp.int32)},
                cfg=cfg, enc=ENC, phase=Phase.DECODE, caches=caches, pos=pos,
            )
            last = int(jnp.argmax(logits[0, -1]))
            toks.append(last)
            pos += 1
        assert got[i] == toks, f"request {i}: {got[i]} vs {toks}"


def test_encoded_vs_reference_model_parity():
    """Table-1 analog at model level: encoding on vs off — same argmax,
    logits close (f32)."""
    cfg = registry.get_reduced("llama3.2-1b")
    enc_on = EncodingConfig(enabled=True, backend="xla")
    enc_off = EncodingConfig(enabled=False, backend="reference")
    params_on = T.model_init(jax.random.PRNGKey(0), cfg, enc_on)
    params_off = T.model_init(jax.random.PRNGKey(0), cfg, enc_off)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 1, cfg.vocab_size)
    lo, _, _ = T.forward(params_on, {"tokens": toks}, cfg=cfg, enc=enc_on, phase=Phase.PREFILL)
    lr, _, _ = T.forward(params_off, {"tokens": toks}, cfg=cfg, enc=enc_off, phase=Phase.PREFILL)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(lr), rtol=1e-3, atol=1e-3)
    assert bool((jnp.argmax(lo, -1) == jnp.argmax(lr, -1)).all())
