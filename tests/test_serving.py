"""Serving invariants: prefill->decode continuity per family, engine
continuous batching, parity of encoded vs reference model (Table-1 analog)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.serving import engine as engine_lib

ENC = EncodingConfig(enabled=True, backend="xla")


def _continuity(arch, tol, **cfg_over):
    cfg = registry.get_reduced(arch, **cfg_over)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 1, cfg.vocab_size)
    full = {"tokens": toks}
    pfx = 0  # logits offset for multimodal prefixes
    if cfg.family == "encdec":
        full["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.frontend_tokens, cfg.d_model)
        )
    if cfg.family == "vlm":
        full["patches"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.frontend_tokens, cfg.frontend_dim)
        )
        pfx = cfg.frontend_tokens
    logits_full, _, _ = T.forward(params, full, cfg=cfg, enc=ENC, phase=Phase.PREFILL)

    sp = s - 4
    caches = T.cache_init(cfg, b, max_seq=s + pfx)
    part = dict(full)
    part["tokens"] = toks[:, :sp]
    logits_p, caches, _ = T.forward(
        params, part, cfg=cfg, enc=ENC, phase=Phase.PREFILL, caches=caches
    )
    errs = [float(jnp.max(jnp.abs(logits_p - logits_full[:, : pfx + sp])))]
    for i in range(sp, s):
        logits_d, caches, _ = T.forward(
            params, {"tokens": toks[:, i : i + 1]},
            cfg=cfg, enc=ENC, phase=Phase.DECODE, caches=caches, pos=pfx + i,
        )
        errs.append(float(jnp.max(jnp.abs(logits_d[:, 0] - logits_full[:, pfx + i]))))
    assert max(errs) < tol, f"{arch}: prefill/decode diverge: {errs}"


@pytest.mark.parametrize("arch,tol", [
    ("qwen2-1.5b", 1e-4),
    ("yi-9b", 1e-4),
    ("rwkv6-1.6b", 1e-4),
    ("recurrentgemma-9b", 1e-4),
    ("whisper-tiny", 1e-4),
    ("internvl2-26b", 1e-4),
])
def test_prefill_decode_continuity(arch, tol):
    _continuity(arch, tol)


def test_moe_continuity_with_unbounded_capacity():
    """Capacity-based token dropping is batch-dependent (expected divergence);
    with non-binding capacity the MoE path must be exactly continuous too."""
    _continuity("mixtral-8x22b", 1e-4, capacity_factor=8.0)


def test_sliding_window_ring_buffer():
    """Decode beyond the window: ring-buffer cache == full-cache windowed attn."""
    cfg = registry.get_reduced("mixtral-8x22b", capacity_factor=8.0, sliding_window=6)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    b, s = 1, 14
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 1, cfg.vocab_size)
    logits_full, _, _ = T.forward(
        params, {"tokens": toks}, cfg=cfg, enc=ENC, phase=Phase.PREFILL
    )
    sp = 4  # prefill less than the window, then decode far past it
    caches = T.cache_init(cfg, b, max_seq=s)
    _, caches, _ = T.forward(
        params, {"tokens": toks[:, :sp]}, cfg=cfg, enc=ENC,
        phase=Phase.PREFILL, caches=caches,
    )
    errs = []
    for i in range(sp, s):
        logits_d, caches, _ = T.forward(
            params, {"tokens": toks[:, i : i + 1]},
            cfg=cfg, enc=ENC, phase=Phase.DECODE, caches=caches, pos=i,
        )
        errs.append(float(jnp.max(jnp.abs(logits_d[:, 0] - logits_full[:, i]))))
    assert max(errs) < 1e-4, errs


def test_engine_continuous_batching():
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    eng = engine_lib.Engine(params, cfg, ENC, slots=2, max_seq=48)
    rng = np.random.RandomState(0)
    for i in range(5):
        plen = rng.randint(3, 9)
        eng.submit(engine_lib.Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=6,
        ))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.generated) == 6 for r in done)


def test_engine_matches_sequential_decode():
    """Engine output == naive one-request-at-a-time decode (greedy)."""
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab_size, rng.randint(3, 7)).astype(np.int32)
               for _ in range(3)]

    eng = engine_lib.Engine(params, cfg, ENC, slots=2, max_seq=32)
    for i, p in enumerate(prompts):
        eng.submit(engine_lib.Request(uid=i, prompt=p, max_new_tokens=5))
    got = {r.uid: r.generated for r in eng.run()}

    for i, p in enumerate(prompts):
        toks = _sequential_decode(params, cfg, p, 5, 32)
        assert got[i] == toks, f"request {i}: {got[i]} vs {toks}"


_count_calls = engine_lib.count_calls


def _skewed_requests(cfg, n=4, seed=3, max_new=6):
    rng = np.random.RandomState(seed)
    # All prompt lengths distinct: worst case for the per-group dispatch loop.
    return [
        engine_lib.Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, 3 + 2 * i).astype(np.int32),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def test_engine_vectorized_matches_grouped_skewed():
    """Position-vectorized decode == per-group baseline, token for token,
    under maximally skewed prompt lengths."""
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    got = {}
    for mode in ("grouped", "vectorized"):
        eng = engine_lib.Engine(
            params, cfg, ENC, slots=4, max_seq=32, decode_mode=mode
        )
        for r in _skewed_requests(cfg):
            eng.submit(r)
        got[mode] = {r.uid: r.generated for r in eng.run()}
    assert got["vectorized"] == got["grouped"]


def test_engine_vectorized_matches_grouped_sliding_window():
    """Per-row ring-buffer scatter + (B,) age mask: vectorized decode matches
    the grouped baseline on a sliding-window config, decoding well past the
    window so every row's ring wraps at a different step."""
    cfg = registry.get_reduced("qwen2-1.5b", sliding_window=6)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    rng = np.random.RandomState(5)
    # Prompts shorter than the window, skewed; decode 10 >> window 6.
    reqs = [
        engine_lib.Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, 2 + i).astype(np.int32),
            max_new_tokens=10,
        )
        for i in range(4)
    ]
    got = {}
    for mode in ("grouped", "vectorized"):
        eng = engine_lib.Engine(
            params, cfg, ENC, slots=4, max_seq=32, decode_mode=mode
        )
        assert not eng.batch_prefill  # windowed: per-slot exact prefill
        for r in reqs:
            eng.submit(dataclasses.replace(r, generated=[]))
        got[mode] = {r.uid: r.generated for r in eng.run()}
    assert got["vectorized"] == got["grouped"]


def test_engine_vectorized_single_decode_dispatch():
    """One engine step == exactly ONE jitted decode call, any position skew."""
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    eng = engine_lib.Engine(params, cfg, ENC, slots=4, max_seq=32)
    for r in _skewed_requests(cfg, max_new=8):
        eng.submit(r)
    eng.step()  # admit everything; all four slots now at distinct positions
    assert all(r is not None for r in eng.slot_req)
    assert len({int(p) for p in eng.slot_pos}) == 4  # positions truly skewed
    eng.decode_fn = _count_calls(eng.decode_fn)
    eng.step()
    assert eng.decode_fn.calls == 1
    # The grouped baseline pays one dispatch per distinct position.
    eng_g = engine_lib.Engine(
        params, cfg, ENC, slots=4, max_seq=32, decode_mode="grouped"
    )
    for r in _skewed_requests(cfg, max_new=8):
        eng_g.submit(r)
    eng_g.step()
    eng_g.decode_fn = _count_calls(eng_g.decode_fn)
    eng_g.step()
    assert eng_g.decode_fn.calls == 4


def test_engine_batched_prefill_single_call():
    """Queued requests with skewed lengths admit in ONE padded prefill call."""
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    eng = engine_lib.Engine(params, cfg, ENC, slots=4, max_seq=32)
    assert eng.batch_prefill  # attention-only, no sliding window
    for r in _skewed_requests(cfg):
        eng.submit(r)
    eng.prefill_fn = _count_calls(eng.prefill_fn)
    eng.step()
    assert eng.prefill_fn.calls == 1
    assert all(r is not None for r in eng.slot_req)


def test_engine_vectorized_falls_back_for_recurrent_state():
    """Recurrent state has no position mask, so an idle slot's rows would
    absorb token-0 updates each vectorized step and later admissions would
    prefill from that garbage.  The engine must fall back to grouped decode —
    and a late-admitted request must generate the same tokens either way."""
    cfg = registry.get_reduced("rwkv6-1.6b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    rng = np.random.RandomState(7)
    pa = rng.randint(1, cfg.vocab_size, 4).astype(np.int32)
    pb = rng.randint(1, cfg.vocab_size, 5).astype(np.int32)
    got = {}
    for mode in ("grouped", "vectorized"):
        eng = engine_lib.Engine(
            params, cfg, ENC, slots=2, max_seq=32, decode_mode=mode
        )
        if mode == "vectorized":
            assert eng.decode_mode == "grouped"  # the guard itself
        eng.submit(engine_lib.Request(uid=0, prompt=pa, max_new_tokens=6))
        for _ in range(3):  # slot 1 idles for 3 steps before B arrives
            eng.step()
        eng.submit(engine_lib.Request(uid=1, prompt=pb, max_new_tokens=6))
        eng.run()
        got[mode] = {r.uid: r.generated for r in eng.finished}
    assert got["vectorized"] == got["grouped"]


def test_engine_rejects_nonpositive_max_new_tokens():
    """max_new_tokens <= 0 finishes immediately: no decode, no slot, no token."""
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    eng = engine_lib.Engine(params, cfg, ENC, slots=2, max_seq=32)
    rng = np.random.RandomState(0)
    eng.submit(engine_lib.Request(
        uid=0, prompt=rng.randint(1, cfg.vocab_size, 4).astype(np.int32),
        max_new_tokens=0,
    ))
    eng.submit(engine_lib.Request(
        uid=1, prompt=rng.randint(1, cfg.vocab_size, 5).astype(np.int32),
        max_new_tokens=3,
    ))
    done = {r.uid: r for r in eng.run()}
    assert done[0].generated == [] and done[0].done
    assert len(done[1].generated) == 3


# ---------------------------------------------------------------------------
# Randomized scheduler-conformance harness: paged/vectorized, dense/vectorized,
# dense/grouped and naive sequential decode must emit token-identical outputs
# on fuzzed request streams (skewed prompt lengths, staggered arrivals, mixed
# max_new_tokens, pool sizes that force preemption, shared prefixes).


def _sequential_decode(params, cfg, prompt, max_new, max_seq):
    """Naive one-request-at-a-time greedy decode — the ground truth."""
    if max_new <= 0:
        return []
    caches = T.cache_init(cfg, 1, max_seq=max_seq)
    _, caches, _ = T.forward(
        params, {"tokens": jnp.asarray(prompt)[None]},
        cfg=cfg, enc=ENC, phase=Phase.PREFILL, caches=caches,
    )
    toks = []
    last = int(prompt[-1])
    pos = len(prompt) - 1
    for _ in range(max_new):
        logits, caches, _ = T.forward(
            params, {"tokens": jnp.asarray([[last]], jnp.int32)},
            cfg=cfg, enc=ENC, phase=Phase.DECODE, caches=caches, pos=pos,
        )
        last = int(jnp.argmax(logits[0, -1]))
        toks.append(last)
        pos += 1
        if pos + 1 >= max_seq:
            break
    return toks


def _run_engine_stream(params, cfg, stream, *, audit=False, **engine_kw):
    """Drive an Engine over (arrival_step, Request) pairs; returns
    ({uid: generated}, engine)."""
    eng = engine_lib.Engine(params, cfg, ENC, **engine_kw)
    pending = sorted(stream, key=lambda t: t[0])
    i = step = 0
    while i < len(pending) or eng.queue or any(
        r is not None for r in eng.slot_req
    ):
        while i < len(pending) and pending[i][0] <= step:
            eng.submit(dataclasses.replace(pending[i][1], generated=[]))
            i += 1
        eng.step()
        if audit:
            eng.audit()
        step += 1
        assert step < 2000, "engine failed to drain the stream"
    return {r.uid: r.generated for r in eng.finished}, eng


def _fuzz_stream(cfg, seed, *, n=6, shared_prefix=False):
    """Seeded request stream: skewed prompt lengths (heavy short tail),
    staggered arrivals, mixed max_new_tokens (including degenerate 0)."""
    rng = np.random.RandomState(seed)
    common = rng.randint(1, cfg.vocab_size, 12).astype(np.int32)
    stream = []
    for i in range(n):
        plen = int(rng.choice([2, 3, 4, 5, 8, 13], p=[0.25, 0.2, 0.2, 0.15, 0.1, 0.1]))
        prompt = rng.randint(1, cfg.vocab_size, plen).astype(np.int32)
        if shared_prefix and rng.rand() < 0.6:
            prompt = np.concatenate(
                [common, rng.randint(1, cfg.vocab_size, rng.randint(1, 4)).astype(np.int32)]
            )
        max_new = int(rng.choice([0, 2, 4, 6, 8], p=[0.1, 0.2, 0.3, 0.2, 0.2]))
        arrival = int(rng.randint(0, 5))
        stream.append((arrival, engine_lib.Request(
            uid=i, prompt=prompt, max_new_tokens=max_new,
        )))
    return stream


@pytest.mark.parametrize("seed,pool", [
    (11, "tight"),    # pool sized to force preemption under decode growth
    (12, "loose"),    # full-coverage pool, pure paging parity
    (13, "prefix"),   # shared prompt prefixes -> page reuse + copy-on-write
])
def test_scheduler_conformance_randomized(seed, pool):
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    max_seq = 48
    stream = _fuzz_stream(cfg, seed, shared_prefix=(pool == "prefix"))
    paged_kw: dict = dict(cache_mode="paged", block_size=4)
    if pool == "tight":
        # Capacity 5: the widest request alone needs 4 pages, so three
        # concurrent slots cannot all grow — decode growth must preempt.
        paged_kw["pool_pages"] = 6
    got = {}
    got["paged"], eng_paged = _run_engine_stream(
        params, cfg, stream, audit=True, slots=3, max_seq=max_seq, **paged_kw
    )
    got["dense_vec"], _ = _run_engine_stream(
        params, cfg, stream, slots=3, max_seq=max_seq, cache_mode="dense"
    )
    got["dense_grouped"], _ = _run_engine_stream(
        params, cfg, stream, slots=3, max_seq=max_seq,
        cache_mode="dense", decode_mode="grouped",
    )
    got["sequential"] = {
        req.uid: _sequential_decode(
            params, cfg, req.prompt, req.max_new_tokens, max_seq
        )
        for _, req in stream
    }
    assert got["paged"] == got["dense_vec"] == got["dense_grouped"] == got["sequential"]
    stats = eng_paged.stats
    if pool == "tight":
        assert stats["preemptions"] > 0, stats  # the stream must exercise eviction
    if pool == "prefix":
        assert stats["shared_hits"] > 0 and stats["cow_events"] > 0, stats
    # Freed-on-finish accounting is exact once the stream drains.
    assert stats["pages_in_use"] == 0 and stats["allocs"] == stats["frees"], stats


def test_encoded_vs_reference_model_parity():
    """Table-1 analog at model level: encoding on vs off — same argmax,
    logits close (f32)."""
    cfg = registry.get_reduced("llama3.2-1b")
    enc_on = EncodingConfig(enabled=True, backend="xla")
    enc_off = EncodingConfig(enabled=False, backend="reference")
    params_on = T.model_init(jax.random.PRNGKey(0), cfg, enc_on)
    params_off = T.model_init(jax.random.PRNGKey(0), cfg, enc_off)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 1, cfg.vocab_size)
    lo, _, _ = T.forward(params_on, {"tokens": toks}, cfg=cfg, enc=enc_on, phase=Phase.PREFILL)
    lr, _, _ = T.forward(params_off, {"tokens": toks}, cfg=cfg, enc=enc_off, phase=Phase.PREFILL)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(lr), rtol=1e-3, atol=1e-3)
    assert bool((jnp.argmax(lo, -1) == jnp.argmax(lr, -1)).all())
