"""w4a8 group-quantized serving path (kernels/mmt4d_q4.py): quantizer
properties, nibble pack/unpack, kernel-vs-oracle parity, and the model-level
decision-preservation harness (margin-aware, the Table-1 bar at 4-bit)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as cfg_registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.kernels import ops, ref
from repro.models import transformer as T


def test_quantize_rows_q4_grouped_bounds_and_shapes():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(24, 100) * 5, jnp.float32)
    q, s = ref.quantize_rows_q4_grouped(x, group=16)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    assert s.shape == (24, 7)  # ceil(100/16)
    assert int(jnp.max(q)) <= 7 and int(jnp.min(q)) >= -7
    # Half-step reconstruction bound holds on the clip-free (absmax) path;
    # the default MSE clip search deliberately trades outlier error for
    # in-range resolution, so it is exempt from this bound.
    q1, s1 = ref.quantize_rows_q4_grouped(x, group=16, ratios=(1.0,))
    sg = np.repeat(np.asarray(s1), 16, axis=1)[:, :100]
    err = np.abs(np.asarray(q1, np.float32) * sg - np.asarray(x))
    assert float(err.max()) <= float(sg.max()) / 2 + 1e-5
    # And the MSE-clip default never does worse than absmax in MSE.
    sgd = np.repeat(np.asarray(s), 16, axis=1)[:, :100]
    mse_clip = np.square(np.asarray(q, np.float32) * sgd - np.asarray(x)).mean()
    mse_abs = np.square(np.asarray(q1, np.float32) * sg - np.asarray(x)).mean()
    assert mse_clip <= mse_abs + 1e-9, (mse_clip, mse_abs)


def test_group_scales_beat_per_row_scales():
    """The point of grouping: one outlier costs its group, not the row."""
    rng = np.random.RandomState(1)
    x = np.asarray(rng.randn(16, 256), np.float32)
    x[:, 0] *= 50.0  # per-row outlier column
    xj = jnp.asarray(x)
    q_g, s_g = ref.quantize_rows_q4_grouped(xj, group=16)
    sg = np.repeat(np.asarray(s_g), 16, axis=1)
    err_g = np.square(np.asarray(q_g, np.float32) * sg - x).mean()
    # Per-row int4 baseline: one scale across all 256 columns.
    q_r, s_r = ref.quantize_rows_q4_grouped(xj, group=256)
    sr = np.repeat(np.asarray(s_r), 256, axis=1)
    err_r = np.square(np.asarray(q_r, np.float32) * sr - x).mean()
    assert err_g < err_r / 10, (err_g, err_r)


def test_pack_unpack_nibbles_roundtrip():
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randint(-8, 8, (3, 5, 64)), jnp.int8)
    packed = ref.pack_nibbles(q)
    assert packed.dtype == jnp.uint8 and packed.shape == (3, 5, 32)
    back = ref.unpack_nibbles(packed)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q, np.int32))


# Ragged M/N/K on purpose: rows, lanes, K and group-boundary padding edges.
MNK_SWEEP = [
    (1, 256, 128),
    (1, 130, 70),
    (4, 132, 200),
    (9, 700, 310),
    (130, 140, 150),
]


@pytest.mark.parametrize("mnk", MNK_SWEEP)
@pytest.mark.parametrize("group", [16, 32])
def test_q4_kernels_match_oracle(mnk, group):
    """fused GEMV and packed mmt4d Pallas kernels == the xla oracle, for the
    default group and the llama.cpp-Q4_0-style g=32."""
    m, n, k = mnk
    rng = np.random.RandomState(m + n)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
    rhs4_p, s_w4 = ops.pack_rhs_q4(w_t, group=group)
    want = ops.encoded_matmul_q4(
        x, rhs4_p, s_w4, n=n, phase=Phase.DECODE, group=group,
        backend="xla", out_dtype=jnp.float32,
    )
    got_f = ops.encoded_matmul_q4(
        x, rhs4_p, s_w4, n=n, phase=Phase.DECODE, group=group,
        backend="fused", out_dtype=jnp.float32, interpret=True,
    )
    got_p = ops.encoded_matmul_q4(
        x, rhs4_p, s_w4, n=n, phase=Phase.PREFILL, group=group,
        backend="pallas", out_dtype=jnp.float32, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got_f), np.asarray(want), rtol=1e-5, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(got_p), np.asarray(want), rtol=1e-5, atol=1e-4
    )


def test_q4_close_to_full_precision():
    m, n, k = 16, 512, 384
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
    exact = ref.matmul_reference(x, w_t)
    rhs4_p, s_w4 = ops.pack_rhs_q4(w_t)
    q4 = ops.encoded_matmul_q4(
        x, rhs4_p, s_w4, n=n, phase=Phase.PREFILL, backend="xla",
        out_dtype=jnp.float32,
    )
    rel = float(jnp.linalg.norm(q4 - exact) / jnp.linalg.norm(exact))
    assert rel < 0.12, rel  # int4 grouped: ~4x the w8a8 bound, still tight


def test_model_level_w4a8_decision_preservation():
    """The decision-preservation harness at 4 bits (margin-aware).

    Bitwise argmax equality at EVERY position is not a 4-bit property — a
    random-init reduced model has near-tied top-2 logits at some positions
    where any rounding flips the pick.  The claims that hold, asserted here:
      * logits stay close: relative MSE < 0.05 (measured 0.036 at the g=16
        serving default; g=32 doubles it — docs/PERF.md),
      * token-identical to the fp reference at every CONFIDENT position
        (fp top-2 margin >= the median margin),
      * bounded regret at flip positions: the w4a8 pick's fp logit is within
        the fp max-margin of the optimum (never a materially worse token),
      * END-TO-END decode continuity: greedy decode through the serving
        cache path emits exactly the tokens the same w4a8 model picks with
        full-context prefill."""
    cfg = cfg_registry.get_reduced("llama3.2-1b")
    enc_fp = EncodingConfig(enabled=True, backend="xla")
    enc_q4 = EncodingConfig(enabled=True, backend="xla", weight_quant="int4")
    p_fp = T.model_init(jax.random.PRNGKey(0), cfg, enc_fp)
    p_q4 = T.model_init(jax.random.PRNGKey(0), cfg, enc_q4)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 1, cfg.vocab_size)
    l_fp, _, _ = T.forward(
        p_fp, {"tokens": toks}, cfg=cfg, enc=enc_fp, phase=Phase.PREFILL
    )
    l_q4, _, _ = T.forward(
        p_q4, {"tokens": toks}, cfg=cfg, enc=enc_q4, phase=Phase.PREFILL
    )
    rel_mse = float(
        jnp.sum(jnp.square(l_q4 - l_fp)) / jnp.sum(jnp.square(l_fp))
    )
    assert rel_mse < 0.05, rel_mse

    am_fp = jnp.argmax(l_fp, -1)
    am_q4 = jnp.argmax(l_q4, -1)
    top2 = jax.lax.top_k(l_fp, 2)[0]
    margin = top2[..., 0] - top2[..., 1]
    med = jnp.median(margin)
    confident = margin >= med
    agree_conf = jnp.sum((am_fp == am_q4) & confident) / jnp.sum(confident)
    assert float(agree_conf) == 1.0, float(agree_conf)
    # Bounded regret everywhere (in fp logit units).
    l_of_q4 = jnp.take_along_axis(l_fp, am_q4[..., None], axis=-1)[..., 0]
    l_of_fp = jnp.take_along_axis(l_fp, am_fp[..., None], axis=-1)[..., 0]
    regret = float(jnp.max(l_of_fp - l_of_q4))
    assert regret <= float(jnp.max(margin)), (regret, float(jnp.max(margin)))

    # End-to-end w4a8 serving continuity: prefill 8 tokens into the cache,
    # greedy-decode 4 more; each decoded argmax must equal the w4a8 model's
    # own full-context prefill argmax at that position.
    sp, b, s = 8, *toks.shape
    caches = T.cache_init(cfg, b, max_seq=s)
    _, caches, _ = T.forward(
        p_q4, {"tokens": toks[:, :sp]}, cfg=cfg, enc=enc_q4,
        phase=Phase.PREFILL, caches=caches,
    )
    for i in range(sp, s):
        l_d, caches, _ = T.forward(
            p_q4, {"tokens": toks[:, i : i + 1]}, cfg=cfg, enc=enc_q4,
            phase=Phase.DECODE, caches=caches, pos=i,
        )
        assert bool(
            (jnp.argmax(l_d[:, 0], -1) == jnp.argmax(l_q4[:, i], -1)).all()
        ), i


def test_w4a8_weight_stream_wins_vs_w8a8():
    """The acceptance bar as a unit test: at the serving default the w4a8
    decode weight stream is >= 1.5x smaller than w8a8 (bytes model)."""
    from repro.core import encoding

    n, k = 2048, 1024
    b8 = encoding.quant_weight_stream_bytes(n, k, quant="w8a8")
    b4 = encoding.quant_weight_stream_bytes(
        n, k, quant="w4a8", group=ref.Q4_GROUP, scale_itemsize=2
    )
    assert b8 / b4 >= 1.5, (b8, b4)
    bf = encoding.quant_weight_stream_bytes(n, k, quant="none")
    assert bf / b4 >= 3.0, (bf, b4)
