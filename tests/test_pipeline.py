"""GPipe pipeline over the pod axis: schedule correctness on a real 2-stage
mesh (subprocess with fake devices)."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

# jax 0.4.37 (the pinned CI minimum) predates jax.sharding.AxisType /
# make_mesh(axis_types=...): these tests exercise the newer-jax SPMD API
# and skip on the pinned leg (they run on the latest-jax CI leg).
requires_axis_types = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType not available on this jax version",
)

_PP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.parallel import pipeline

    mesh = jax.make_mesh((2,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
    L, D, M, B = 4, 16, 3, 5   # 4 layers -> 2 stages x 2 layers
    key = jax.random.PRNGKey(0)
    ws = 0.3 * jax.random.normal(key, (L, D, D), jnp.float32)

    def layer(w, x):
        return jnp.tanh(x @ w)

    def stage(params, x):           # params: (L/S, D, D)
        def body(c, w):
            return layer(w, c), None
        y, _ = jax.lax.scan(body, x, params)
        return y

    xs = jax.random.normal(jax.random.PRNGKey(1), (M, B, D), jnp.float32)

    # Reference: all layers sequentially per microbatch.
    def full(x):
        y, _ = jax.lax.scan(lambda c, w: (layer(w, c), None), x, ws)
        return y
    want = jax.vmap(full)(xs)

    stage_params = pipeline.stack_stages(ws, 2)
    with jax.set_mesh(mesh):
        sp = jax.device_put(stage_params, jax.NamedSharding(mesh, P("pod")))
        got = jax.jit(lambda p, x: pipeline.gpipe_forward(
            stage, p, x, mesh=mesh, axis="pod"))(sp, xs)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-5, err
    # ppermute (the inter-pod hop) must appear in the compiled program.
    hlo = jax.jit(lambda p, x: pipeline.gpipe_forward(
        stage, p, x, mesh=mesh, axis="pod")).lower(sp, xs).compile().as_text()
    assert "collective-permute" in hlo
    print("PP_OK", err)
""")


@requires_axis_types
def test_gpipe_two_stage_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    r = subprocess.run(
        [sys.executable, "-c", _PP_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "PP_OK" in r.stdout


def test_stack_stages_shapes():
    import jax.numpy as jnp
    from repro.parallel import pipeline

    tree = {"w": jnp.zeros((8, 3, 3)), "b": jnp.zeros((8, 3))}
    st = pipeline.stack_stages(tree, 4)
    assert st["w"].shape == (4, 2, 3, 3)
    assert st["b"].shape == (4, 2, 3)
