"""Chunked prefill == single-shot prefill (same caches, same next-token path),
and the batch_mmt4d kernel vs its oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.kernels.batch_mmt4d import batch_mmt4d_pallas, batch_mmt4d_ref
from repro.models import transformer as T
from repro.serving import engine as engine_lib

ENC = EncodingConfig(enabled=True, backend="xla")


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-1.6b", "yi-9b"])
def test_chunked_prefill_matches_single_shot(arch):
    cfg = registry.get_reduced(arch)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    b, s, chunk = 2, 24, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 1, cfg.vocab_size)

    caches1 = T.cache_init(cfg, b, max_seq=s + 4)
    logits1, caches1, _ = T.forward(
        params, {"tokens": toks}, cfg=cfg, enc=ENC, phase=Phase.PREFILL,
        caches=caches1, last_logits_only=True,
    )

    caches2 = T.cache_init(cfg, b, max_seq=s + 4)
    prefill_chunked = engine_lib.make_chunked_prefill_step(cfg, ENC, chunk=chunk)
    logits2, caches2 = prefill_chunked(params, toks, caches2)

    np.testing.assert_allclose(
        np.asarray(logits1), np.asarray(logits2), rtol=2e-4, atol=2e-4
    )
    # Decode continues identically from either cache.
    tok = toks[:, -1:]
    d1, _, _ = T.forward(params, {"tokens": tok}, cfg=cfg, enc=ENC,
                         phase=Phase.DECODE, caches=caches1, pos=s)
    d2, _, _ = T.forward(params, {"tokens": tok}, cfg=cfg, enc=ENC,
                         phase=Phase.DECODE, caches=caches2, pos=s)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=2e-4, atol=2e-4)


def test_chunked_prefill_rejects_window_wider_than_chunk():
    """window > chunk would silently drop cross-chunk attention (the
    windowed prefill path never concatenates earlier chunks back in) — the
    constructor must refuse instead of producing wrong logits."""
    cfg = registry.get_reduced("qwen2-1.5b", sliding_window=16)
    with pytest.raises(ValueError, match="sliding_window <= chunk"):
        engine_lib.make_chunked_prefill_step(cfg, ENC, chunk=8)
    # window <= chunk keeps building (the documented supported regime).
    engine_lib.make_chunked_prefill_step(cfg, ENC, chunk=16)
    engine_lib.make_chunked_prefill_step(cfg, ENC, chunk=32)


@pytest.mark.parametrize("shape", [(2, 2, 3, 16, 8, 8), (3, 4, 2, 8, 32, 16)])
def test_batch_mmt4d_kernel(shape):
    bsz, m1, k1, m0, n0 = shape[0], shape[1], shape[2], shape[3], shape[4]
    k0 = shape[5]
    n1 = m1 + 1
    rng = np.random.RandomState(0)
    lhs = jnp.asarray(rng.randn(bsz, m1, k1, m0, k0), jnp.float32)
    rhs = jnp.asarray(rng.randn(bsz, n1, k1, n0, k0), jnp.float32)
    want = batch_mmt4d_ref(lhs, rhs)
    got = batch_mmt4d_pallas(lhs, rhs, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)
    got2 = batch_mmt4d_pallas(lhs, rhs, blocks=(m1, 1, k1), interpret=True)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want), rtol=1e-5, atol=1e-4)
