"""Roofline analyzer: loop-multiplier correctness on controlled programs."""

import sys
import os

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import hlo_analysis as H  # noqa: E402


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops_exact():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    hlo = _hlo(lambda a, b: a @ b, x, x)
    a = H.analyze(hlo)
    assert a["flops"] == 2 * 256**3


def test_scan_multiplies_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    a1 = H.analyze(_hlo(lambda a, b: a @ b, x, x))
    a10 = H.analyze(_hlo(scanned, x, ws))
    assert abs(a10["flops"] / a1["flops"] - 10.0) < 1e-6


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)

    def nested(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return c2 @ w, None
            c3, _ = jax.lax.scan(inner, c, jnp.arange(5))
            return c3, None
        return jax.lax.scan(outer, x, ws)[0]

    a1 = H.analyze(_hlo(lambda a, b: a @ b, x, x))
    a20 = H.analyze(_hlo(nested, x, ws))
    assert abs(a20["flops"] / a1["flops"] - 20.0) < 1e-6


def test_bytes_nonzero_and_scale_with_scan():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws2 = jax.ShapeDtypeStruct((2, 128, 128), jnp.float32)
    ws8 = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    b2 = H.analyze(_hlo(scanned, x, ws2))["hbm_bytes"]
    b8 = H.analyze(_hlo(scanned, x, ws8))["hbm_bytes"]
    assert b8 > 2.5 * b2  # roughly linear in trip count


def test_no_collectives_on_single_device():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    a = H.analyze(_hlo(lambda a: a @ a, x))
    assert a["collective_bytes"] == 0.0
