"""Speculative decode: prompt-lookup drafter, batched multi-token verify,
greedy token-identity (dense + paged, ragged acceptance, preemption in the
stream), paged rollback accounting, EOS early-finish, and sampled decode.

The load-bearing invariant: spec decode commits a draft token ONLY when it
equals the model's own greedy argmax, so engine output is token-identical to
plain greedy decode for ANY drafter — the tests drive the real prompt-lookup
drafter, a full-knowledge oracle (maximum acceptance) and an adversarial
always-wrong drafter (maximum rollback) through the same harness.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.serving import engine as engine_lib
from repro.serving import spec as spec_lib

ENC = EncodingConfig(enabled=True, backend="xla")


@pytest.fixture(scope="module")
def model():
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    return cfg, params


# ---------------------------------------------------------------------------
# Drafter


def test_drafter_proposes_continuation_of_most_recent_match():
    ctx = np.array([5, 6, 7, 8, 5, 6, 7], np.int32)
    np.testing.assert_array_equal(spec_lib.propose(ctx, 3), [8, 5, 6])


def test_drafter_recency_wins():
    # The 2-gram (1, 2) occurs twice; the LATER occurrence's continuation (9)
    # must win over the earlier one's (3).
    ctx = np.array([1, 2, 3, 4, 1, 2, 9, 1, 2], np.int32)
    np.testing.assert_array_equal(spec_lib.propose(ctx, 1, ngram=2), [9])


def test_drafter_falls_back_to_shorter_ngrams():
    # No trailing 3- or 2-gram recurs, but the last token does.
    ctx = np.array([7, 1, 7, 2, 7], np.int32)
    got = spec_lib.propose(ctx, 2, ngram=3)
    np.testing.assert_array_equal(got, [2, 7])  # after the ctx[2] match


def test_drafter_empty_on_no_match_and_degenerate_inputs():
    assert spec_lib.propose(np.array([1, 2, 3, 4], np.int32), 3).size == 0
    assert spec_lib.propose(np.array([1], np.int32), 3).size == 0
    assert spec_lib.propose(np.array([], np.int32), 3).size == 0
    assert spec_lib.propose(np.array([1, 1, 2], np.int32), 0).size == 0


def test_drafter_truncates_to_k():
    ctx = np.array([1, 2, 3, 4, 5, 1, 2], np.int32)
    np.testing.assert_array_equal(spec_lib.propose(ctx, 2, ngram=2), [3, 4])


# ---------------------------------------------------------------------------
# Verify step: one (B, L) decode dispatch == L sequential one-token decodes


def test_verify_step_matches_sequential_decode(model):
    cfg, params = model
    b, sp, L = 2, 6, 4
    toks = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (b, sp + L), 1, cfg.vocab_size)
    )
    caches = T.cache_init(cfg, b, max_seq=16)
    _, caches, _ = T.forward(
        params, {"tokens": jnp.asarray(toks[:, :sp])},
        cfg=cfg, enc=ENC, phase=Phase.PREFILL, caches=caches,
    )
    seq = []
    c1 = caches
    for i in range(sp - 1, sp - 1 + L):
        lg, c1, _ = T.forward(
            params, {"tokens": jnp.asarray(toks[:, i : i + 1])},
            cfg=cfg, enc=ENC, phase=Phase.DECODE, caches=c1,
            pos=np.full((b,), i, np.int32),
        )
        seq.append(np.asarray(lg[:, 0]))
    verify = engine_lib.make_verify_step(cfg, ENC)
    lg2, _ = verify(
        params, caches,
        jnp.asarray(toks[:, sp - 1 : sp - 1 + L]),
        jnp.full((b,), sp - 1, jnp.int32),
    )
    err = float(np.max(np.abs(np.asarray(lg2) - np.stack(seq, 1))))
    assert err < 1e-4, err


# ---------------------------------------------------------------------------
# Harness: engine streams, sequential ground truth, drafter plugins


def _sequential_decode(params, cfg, prompt, max_new, max_seq, eos_id=None):
    """Naive one-request-at-a-time greedy decode — the ground truth."""
    if max_new <= 0:
        return []
    caches = T.cache_init(cfg, 1, max_seq=max_seq)
    _, caches, _ = T.forward(
        params, {"tokens": jnp.asarray(prompt)[None]},
        cfg=cfg, enc=ENC, phase=Phase.PREFILL, caches=caches,
    )
    toks = []
    last = int(prompt[-1])
    pos = len(prompt) - 1
    for _ in range(max_new):
        logits, caches, _ = T.forward(
            params, {"tokens": jnp.asarray([[last]], jnp.int32)},
            cfg=cfg, enc=ENC, phase=Phase.DECODE, caches=caches, pos=pos,
        )
        last = int(jnp.argmax(logits[0, -1]))
        toks.append(last)
        pos += 1
        if eos_id is not None and last == eos_id:
            break
        if pos + 1 >= max_seq:
            break
    return toks


def _run_stream(params, cfg, stream, **engine_kw):
    """Drive an Engine over (arrival_step, Request) pairs with an audit every
    step; returns ({uid: generated}, engine)."""
    eng = engine_lib.Engine(params, cfg, ENC, **engine_kw)
    pending = sorted(stream, key=lambda t: t[0])
    i = step = 0
    while i < len(pending) or eng.queue or any(
        r is not None for r in eng.slot_req
    ):
        while i < len(pending) and pending[i][0] <= step:
            eng.submit(dataclasses.replace(
                pending[i][1], generated=[], draft_proposed=0, draft_accepted=0,
            ))
            i += 1
        eng.step()
        eng.audit()
        step += 1
        assert step < 2000, "engine failed to drain the stream"
    return {r.uid: r.generated for r in eng.finished}, eng


def _spec_stream(cfg, seed, *, n=5):
    """Mixed stream: repetition-heavy prompts (prompt-lookup territory, high
    acceptance) interleaved with incompressible random prompts (no drafts —
    the fallback path), staggered arrivals, mixed budgets."""
    rng = np.random.RandomState(seed)
    stream = []
    for i in range(n):
        if i % 2 == 0:
            phrase = rng.randint(1, cfg.vocab_size, rng.randint(2, 4)).astype(np.int32)
            prompt = np.tile(phrase, rng.randint(3, 5))
        else:
            prompt = rng.randint(1, cfg.vocab_size, rng.randint(3, 9)).astype(np.int32)
        max_new = int(rng.choice([2, 4, 6, 8]))
        stream.append((int(rng.randint(0, 4)), engine_lib.Request(
            uid=i, prompt=prompt.astype(np.int32), max_new_tokens=max_new,
        )))
    return stream


def _adversarial_drafter(context, k):
    """Always-wrong drafts (vocab id 1 is never the tiny model's argmax for
    these streams in practice — and even when it is, identity still holds):
    exercises full rejection + rollback every single step."""
    return np.full((k,), 1, np.int32)


# ---------------------------------------------------------------------------
# Token identity: greedy spec decode == baseline greedy decode


@pytest.mark.parametrize("cache_mode,pool", [
    ("dense", None),
    ("paged", None),      # full-coverage pool: pure verify/rollback parity
    ("paged", "tight"),   # draft growth under pool pressure -> preemption
])
def test_spec_decode_token_identity(model, cache_mode, pool):
    cfg, params = model
    max_seq = 48
    stream = _spec_stream(cfg, seed=21)
    kw: dict = dict(slots=3, max_seq=max_seq, cache_mode=cache_mode)
    if cache_mode == "paged":
        kw["block_size"] = 4
        if pool == "tight":
            kw["pool_pages"] = 8  # forces eviction once drafts grow pages
    want = {
        req.uid: _sequential_decode(params, cfg, req.prompt, req.max_new_tokens, max_seq)
        for _, req in stream
    }
    got, eng = _run_stream(
        params, cfg, stream, spec_decode=True, draft_k=3, **kw
    )
    assert got == want
    st = eng.stats["spec"]
    # The repetition-heavy half of the stream must actually speculate (ragged
    # acceptance: proposals exist; with a roomy pool some get accepted —
    # under tight-pool pressure speculation may stand down every step).
    assert st["proposed"] > 0 or (pool == "tight" and st["pool_deferred"] > 0)
    if pool != "tight":
        assert st["accepted"] > 0
    if cache_mode == "paged":
        if pool == "tight":
            # Pool pressure must surface as baseline-growth preemption and/or
            # speculation standing down (drafts must never preempt a live
            # request to fund their pages — engine._draft_pages_fit).
            assert eng.preemptions > 0 or st["pool_deferred"] > 0, eng.stats
        else:
            assert st["pool_deferred"] == 0, eng.stats
        assert eng.stats["pages_in_use"] == 0
        assert eng.stats["allocs"] == eng.stats["frees"]


@pytest.mark.parametrize("arch", ["llama3.2-1b", "yi-9b"])
def test_spec_decode_token_identity_other_archs(arch):
    """The identity invariant holds across the attn-only zoo, not just the
    harness default (GQA ratios and head counts differ per arch)."""
    cfg = registry.get_reduced(arch)
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    max_seq = 32
    rng = np.random.RandomState(31)
    phrase = rng.randint(1, cfg.vocab_size, 3).astype(np.int32)
    stream = [
        (0, engine_lib.Request(uid=0, prompt=np.tile(phrase, 4), max_new_tokens=6)),
        (1, engine_lib.Request(
            uid=1, prompt=rng.randint(1, cfg.vocab_size, 5).astype(np.int32),
            max_new_tokens=5,
        )),
    ]
    want = {
        req.uid: _sequential_decode(params, cfg, req.prompt, req.max_new_tokens, max_seq)
        for _, req in stream
    }
    got, _ = _run_stream(
        params, cfg, stream, spec_decode=True, draft_k=3,
        slots=2, max_seq=max_seq, cache_mode="paged", block_size=4,
    )
    assert got == want


def test_spec_decode_identity_under_adversarial_drafter(model):
    """Every draft rejected, every step: output must STILL be token-identical
    and the paged allocator must survive constant rollback."""
    cfg, params = model
    max_seq = 48
    stream = _spec_stream(cfg, seed=22, n=4)
    want = {
        req.uid: _sequential_decode(params, cfg, req.prompt, req.max_new_tokens, max_seq)
        for _, req in stream
    }
    got, eng = _run_stream(
        params, cfg, stream, spec_decode=True, draft_k=4,
        drafter=_adversarial_drafter,
        slots=2, max_seq=max_seq, cache_mode="paged", block_size=2,
    )
    assert got == want
    st = eng.stats["spec"]
    assert st["proposed"] > 0
    # Wrong drafts commit exactly the bonus token — plain-decode pace.
    assert st["committed"] == st["slot_steps"] + st["accepted"]
    # Rollback really freed draft-only pages: far more page churn than the
    # committed sequences alone would ever need.
    committed_blocks = sum(
        (len(req.prompt) + len(got[req.uid]) + 1) // 2 + 1 for _, req in stream
    )
    assert eng.stats["frees"] > committed_blocks, eng.stats
    assert eng.stats["pages_in_use"] == 0
    assert eng.stats["allocs"] == eng.stats["frees"]


def test_spec_decode_oracle_drafter_amortizes_dispatches(model):
    """A full-knowledge drafter makes every draft accepted: per-slot verify
    dispatches collapse to ceil(T / (k+1)) — the acceptance->amortization
    contract the bench gates (docs/PERF.md)."""
    cfg, params = model
    max_seq, max_new, k = 64, 12, 3
    rng = np.random.RandomState(7)
    prompt = rng.randint(2, cfg.vocab_size, 5).astype(np.int32)
    target = _sequential_decode(params, cfg, prompt, max_new, max_seq)
    full = np.concatenate([prompt, np.asarray(target, np.int32)])

    def oracle(context, kk):
        ctx = np.asarray(context, np.int32)
        assert np.array_equal(ctx, full[: ctx.size]), "oracle fed unknown ctx"
        return full[ctx.size : ctx.size + kk]

    eng = engine_lib.Engine(
        params, cfg, ENC, slots=1, max_seq=max_seq,
        spec_decode=True, draft_k=k, drafter=oracle,
    )
    eng.decode_fn = engine_lib.count_calls(eng.decode_fn)
    eng.verify_fn = engine_lib.count_calls(eng.verify_fn)
    eng.submit(engine_lib.Request(uid=0, prompt=prompt, max_new_tokens=max_new))
    done = eng.run()
    assert done[0].generated == target
    st = eng.stats["spec"]
    assert st["accepted"] == st["proposed"] > 0       # oracle: 100% acceptance
    assert st["mean_accepted_len"] > 1.0
    dispatches = eng.decode_fn.calls + eng.verify_fn.calls
    assert dispatches == -(-max_new // (k + 1)), (dispatches, max_new)
    assert done[0].draft_accepted == done[0].draft_proposed > 0


# ---------------------------------------------------------------------------
# EOS / stop tokens


def _eos_from_baseline(params, cfg, prompt, max_seq, idx=2):
    """Pick the token the greedy baseline emits at step `idx` as the EOS —
    guarantees the stream actually hits it mid-request."""
    base = _sequential_decode(params, cfg, prompt, idx + 1, max_seq)
    return base[idx]


@pytest.mark.parametrize("spec", [False, True])
def test_eos_finishes_slot_early_and_decode_continues(model, spec):
    """A request stopping at EOS must (a) keep the EOS, emit nothing after
    it, (b) free its pages, and (c) leave the engine state clean enough that
    a later request decodes token-identically (decode continuity)."""
    cfg, params = model
    max_seq = 48
    rng = np.random.RandomState(11)
    phrase = rng.randint(2, cfg.vocab_size, 3).astype(np.int32)
    p_eos = np.tile(phrase, 4)       # repetition-heavy: spec path exercises
    p_after = rng.randint(2, cfg.vocab_size, 6).astype(np.int32)
    eos = _eos_from_baseline(params, cfg, p_eos, max_seq)
    want_eos = _sequential_decode(params, cfg, p_eos, 10, max_seq, eos_id=eos)
    assert want_eos[-1] == eos and len(want_eos) < 10  # EOS really cuts it short
    want_after = _sequential_decode(params, cfg, p_after, 6, max_seq)

    eng = engine_lib.Engine(
        params, cfg, ENC, slots=1, max_seq=max_seq,
        cache_mode="paged", block_size=4, spec_decode=spec, draft_k=3,
    )
    eng.submit(engine_lib.Request(
        uid=0, prompt=p_eos, max_new_tokens=10, eos_id=eos,
    ))
    eng.submit(engine_lib.Request(uid=1, prompt=p_after, max_new_tokens=6))
    while eng.queue or any(r is not None for r in eng.slot_req):
        eng.step()
        eng.audit()
    got = {r.uid: r.generated for r in eng.finished}
    assert got[0] == want_eos, "post-EOS tokens emitted or EOS missed"
    assert got[1] == want_after, "slot reuse after EOS broke decode continuity"
    assert eng.stats["pages_in_use"] == 0


def test_eos_in_middle_of_accepted_draft_window(model):
    """EOS landing inside an accepted draft run must truncate the commit at
    the EOS even though later drafts also matched."""
    cfg, params = model
    max_seq, max_new, k = 64, 12, 4
    rng = np.random.RandomState(13)
    prompt = rng.randint(2, cfg.vocab_size, 5).astype(np.int32)
    target = _sequential_decode(params, cfg, prompt, max_new, max_seq)
    eos = target[4]  # mid-sequence; with k=4 a draft window can straddle it
    want = target[: target.index(eos) + 1]
    full = np.concatenate([prompt, np.asarray(target, np.int32)])

    def oracle(context, kk):
        ctx = np.asarray(context, np.int32)
        return full[ctx.size : ctx.size + kk]

    eng = engine_lib.Engine(
        params, cfg, ENC, slots=1, max_seq=max_seq,
        spec_decode=True, draft_k=k, drafter=oracle,
    )
    eng.submit(engine_lib.Request(
        uid=0, prompt=prompt, max_new_tokens=max_new, eos_id=eos,
    ))
    done = eng.run()
    assert done[0].generated == want


# ---------------------------------------------------------------------------
# Sampling (make_decode_step sample=...)


def test_temperature_zero_rows_match_greedy(model):
    """sample="temperature" with temp<=0 rows must reproduce argmax exactly."""
    cfg, params = model
    decode_g = jax.jit(engine_lib.make_decode_step(cfg, ENC))
    decode_s = jax.jit(engine_lib.make_decode_step(cfg, ENC, sample="temperature"))
    b, sp = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, sp), 1, cfg.vocab_size)
    caches = T.cache_init(cfg, b, max_seq=16)
    _, caches, _ = T.forward(
        params, {"tokens": toks}, cfg=cfg, enc=ENC, phase=Phase.PREFILL,
        caches=caches,
    )
    tok = toks[:, -1:]
    pos = jnp.asarray(sp - 1, jnp.int32)
    g, _, _ = decode_g(params, caches, tok, pos)
    key = jax.random.PRNGKey(3)
    z, _, _ = decode_s(params, caches, tok, pos, key, jnp.zeros((b,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(g), np.asarray(z))
    # temp > 0 is deterministic given the key...
    t = jnp.full((b,), 5.0, jnp.float32)
    s1, _, _ = decode_s(params, caches, tok, pos, key, t)
    s2, _, _ = decode_s(params, caches, tok, pos, key, t)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # ...and a hot enough temperature eventually departs from argmax.
    diff = False
    for i in range(8):
        si, _, _ = decode_s(
            params, caches, tok, pos, jax.random.PRNGKey(100 + i),
            jnp.full((b,), 50.0, jnp.float32),
        )
        diff = diff or not np.array_equal(np.asarray(si), np.asarray(g))
    assert diff, "temperature-50 sampling never left the argmax"


def test_engine_sampled_greedy_requests_match_greedy_engine(model):
    """An engine built for sampling serves temperature=0 requests exactly as
    the greedy engine does (PRNG threading must not perturb greedy rows)."""
    cfg, params = model
    rng = np.random.RandomState(17)
    prompts = [rng.randint(1, cfg.vocab_size, 4 + i).astype(np.int32) for i in range(3)]

    def run(sample):
        eng = engine_lib.Engine(
            params, cfg, ENC, slots=2, max_seq=32, sample=sample, seed=9,
        )
        for i, p in enumerate(prompts):
            eng.submit(engine_lib.Request(
                uid=i, prompt=p, max_new_tokens=5, temperature=0.0,
            ))
        return {r.uid: r.generated for r in eng.run()}

    assert run("temperature") == run("greedy")


def test_engine_sampling_deterministic_per_seed(model):
    cfg, params = model
    rng = np.random.RandomState(19)
    prompts = [rng.randint(1, cfg.vocab_size, 5).astype(np.int32) for _ in range(2)]

    def run(seed):
        eng = engine_lib.Engine(
            params, cfg, ENC, slots=2, max_seq=32,
            sample="temperature", seed=seed, cache_mode="dense",
        )
        for i, p in enumerate(prompts):
            eng.submit(engine_lib.Request(
                uid=i, prompt=p, max_new_tokens=6, temperature=2.0,
            ))
        return {r.uid: r.generated for r in eng.run()}

    assert run(5) == run(5)  # same seed, same stream


def test_spec_decode_disabled_under_sampling(model):
    """No greedy target to verify against -> speculation must switch off."""
    cfg, params = model
    eng = engine_lib.Engine(
        params, cfg, ENC, slots=2, max_seq=32,
        sample="temperature", spec_decode=True,
    )
    assert not eng.spec_decode
    # ...and stays on for the greedy twin.
    eng2 = engine_lib.Engine(
        params, cfg, ENC, slots=2, max_seq=32, spec_decode=True,
    )
    assert eng2.spec_decode


def test_make_decode_step_rejects_unknown_sample_mode(model):
    cfg, _ = model
    with pytest.raises(ValueError, match="sample"):
        engine_lib.make_decode_step(cfg, ENC, sample="nucleus")
