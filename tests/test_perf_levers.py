"""Beyond-paper §Perf levers must be numerically exact vs the baseline path
(they are sharding/scheduling changes, not approximations)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.models.layers import attention_chunked

ENC = EncodingConfig(enabled=True, backend="xla")


def _logits(cfg, params, toks):
    l, _, _ = T.forward(params, {"tokens": toks}, cfg=cfg, enc=ENC, phase=Phase.PREFILL)
    return l


def test_expand_kv_pad_bands_model_exact():
    cfg0 = registry.get_reduced("qwen2.5-14b")
    cfg0 = dataclasses.replace(cfg0, num_heads=6, num_kv_heads=2)
    cfg1 = dataclasses.replace(
        cfg0, tp_attn_expand_kv=True, pad_attn_heads_to=4, causal_bands=3
    )
    params = T.model_init(jax.random.PRNGKey(0), cfg0, ENC)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 1, cfg0.vocab_size)
    np.testing.assert_allclose(
        np.asarray(_logits(cfg0, params, toks)),
        np.asarray(_logits(cfg1, params, toks)),
        rtol=2e-4, atol=2e-4,
    )


def test_causal_bands_attention_exact():
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(2, 50, 4, 8), jnp.float32)
    k = jnp.asarray(rng.randn(2, 50, 2, 8), jnp.float32)
    v = jnp.asarray(rng.randn(2, 50, 2, 8), jnp.float32)
    base = attention_chunked(q, k, v, causal=True, window=0, q_chunk=8, kv_chunk=8)
    for bands in (2, 3, 7):
        got = attention_chunked(
            q, k, v, causal=True, window=0, q_chunk=8, kv_chunk=8, causal_bands=bands
        )
        np.testing.assert_allclose(np.asarray(base), np.asarray(got), atol=1e-5)


def test_dense_decode_matches_dispatch_decode():
    cfg0 = registry.get_reduced("mixtral-8x22b", capacity_factor=16.0)
    cfg1 = dataclasses.replace(cfg0, moe_dense_decode=True)
    params = T.model_init(jax.random.PRNGKey(0), cfg0, ENC)
    b = 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, 9), 1, cfg0.vocab_size)
    caches0 = T.cache_init(cfg0, b, 16)
    caches1 = T.cache_init(cfg1, b, 16)
    _, caches0, _ = T.forward(params, {"tokens": toks[:, :8]}, cfg=cfg0, enc=ENC,
                              phase=Phase.PREFILL, caches=caches0)
    _, caches1, _ = T.forward(params, {"tokens": toks[:, :8]}, cfg=cfg1, enc=ENC,
                              phase=Phase.PREFILL, caches=caches1)
    l0, _, _ = T.forward(params, {"tokens": toks[:, 8:9]}, cfg=cfg0, enc=ENC,
                         phase=Phase.DECODE, caches=caches0, pos=8)
    l1, _, _ = T.forward(params, {"tokens": toks[:, 8:9]}, cfg=cfg1, enc=ENC,
                         phase=Phase.DECODE, caches=caches1, pos=8)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-4, atol=1e-4)


def test_grouped_dispatch_no_drop_exact():
    cfg0 = registry.get_reduced("mixtral-8x22b", capacity_factor=16.0)
    cfg1 = dataclasses.replace(cfg0, moe_dispatch_groups=4)
    params = T.model_init(jax.random.PRNGKey(0), cfg0, ENC)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 1, cfg0.vocab_size)
    np.testing.assert_allclose(
        np.asarray(_logits(cfg0, params, toks)),
        np.asarray(_logits(cfg1, params, toks)),
        rtol=1e-5, atol=1e-5,
    )


def test_moe_shard_map_falls_back_on_cpu():
    """Without an ambient mesh the shard_map flag must be a no-op."""
    cfg1 = registry.get_reduced("mixtral-8x22b", capacity_factor=16.0, moe_shard_map=True)
    cfg0 = dataclasses.replace(cfg1, moe_shard_map=False)
    params = T.model_init(jax.random.PRNGKey(0), cfg0, ENC)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 1, cfg0.vocab_size)
    np.testing.assert_allclose(
        np.asarray(_logits(cfg0, params, toks)),
        np.asarray(_logits(cfg1, params, toks)),
        rtol=1e-6, atol=1e-6,
    )


def test_last_logits_only():
    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 1, cfg.vocab_size)
    full, _, _ = T.forward(params, {"tokens": toks}, cfg=cfg, enc=ENC, phase=Phase.PREFILL)
    last, _, _ = T.forward(params, {"tokens": toks}, cfg=cfg, enc=ENC,
                           phase=Phase.PREFILL, last_logits_only=True)
    assert last.shape == (2, 1, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(last), atol=1e-5)


def test_bf16_moments_still_train():
    from repro.data import pipeline as data_lib
    from repro.train import optimizer as opt_lib, trainer as trainer_lib

    cfg = registry.get_reduced("qwen2-1.5b")
    params = T.model_init(jax.random.PRNGKey(0), cfg, ENC)
    ocfg = opt_lib.OptimizerConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=50,
                                   moment_dtype="bfloat16")
    opt_state = opt_lib.init(params, ocfg)
    assert jax.tree.leaves(opt_state["mu"])[0].dtype == jnp.bfloat16
    data = data_lib.SyntheticPacked(data_lib.DataConfig(cfg.vocab_size, 32, 8))
    step = jax.jit(trainer_lib.make_train_step(cfg, ENC, ocfg))
    losses = []
    for i in range(15):
        params, opt_state, m, _ = step(params, opt_state, jax.tree.map(jnp.asarray, data.batch(i)))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
