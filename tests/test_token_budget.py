"""Token-budget continuous batching (serving/engine.py): the unified mixed
chunked-prefill + decode dispatch, its scheduler (SLO classes, aging,
preemption ordering), and the latent-scheduler-bug sweep that rode along.

The load-bearing contract is TOKEN IDENTITY: for any arrival pattern, the
mixed engine must emit exactly what the phase-split engine emits (which is
itself pinned token-identical between paged / dense / grouped elsewhere) —
chunk boundaries, window padding, budget splits, and spec windows are all
invisible in the output.  On top of that, the stall metric the whole design
exists for: a long prompt admitted mid-decode must cost ZERO decode-stall
steps (every live decoding slot emits every step), gated here and in
benchmarks/check_regression.py.
"""

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.packed import EncodingConfig
from repro.kernels import registry as registry_lib
from repro.models import transformer as T
from repro.serving import engine as engine_lib
from repro.serving import spec as spec_lib

ENC = EncodingConfig(enabled=True, backend="xla")
CFG = registry.get_reduced("qwen2-1.5b")
PARAMS = T.model_init(jax.random.PRNGKey(0), CFG, ENC)


@pytest.fixture(autouse=True)
def _clean_quarantine():
    registry_lib.clear_quarantine()
    yield
    registry_lib.clear_quarantine()


def _prompts(seed=0, n=5, lo=4, hi=12):
    rng = np.random.RandomState(seed)
    return [
        rng.randint(1, CFG.vocab_size, rng.randint(lo, hi)).astype(np.int32)
        for _ in range(n)
    ]


def _engine(**kw):
    kw.setdefault("slots", 3)
    kw.setdefault("max_seq", 64)
    return engine_lib.Engine(PARAMS, CFG, ENC, **kw)


def _drive(eng, budget=400, audit=True):
    steps = 0
    while eng.queue or any(r is not None for r in eng.slot_req):
        assert steps < budget, "engine did not drain"
        eng.step()
        if audit:
            eng.audit()
        steps += 1
    return {r.uid: list(r.generated) for r in eng.finished}


def _submit_all(eng, prompts, max_new=8, **req_kw):
    for i, p in enumerate(prompts):
        assert eng.submit(engine_lib.Request(
            uid=i, prompt=p, max_new_tokens=max_new, **req_kw
        ))


# ---------------------------------------------------------------------------
# Token identity: mixed == sequential, all cache modes, spec on and off
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cache_mode", ["paged", "dense"])
def test_mixed_token_identity(cache_mode):
    prompts = _prompts()
    ref = _engine(cache_mode=cache_mode)
    _submit_all(ref, prompts)
    gold = _drive(ref)

    eng = _engine(cache_mode=cache_mode, token_budget=10)
    _submit_all(eng, prompts)
    got = _drive(eng)
    assert eng.scheduler is not None
    assert got == gold
    c = eng.stats["continuous"]
    assert c["mixed_steps"] > 0 and c["prefill_tokens"] > 0
    assert c["decode_stall_steps"] == 0


def test_mixed_token_identity_with_spec_decode():
    # Repetitive prompts so the prompt-lookup drafter actually proposes;
    # spec windows and prefill chunks then share one budget.
    rng = np.random.RandomState(3)
    prompts = [
        np.tile(rng.randint(1, CFG.vocab_size, 5), 4).astype(np.int32)
        for _ in range(4)
    ]
    ref = _engine(cache_mode="paged")
    _submit_all(ref, prompts, max_new=10)
    gold = _drive(ref)

    eng = _engine(cache_mode="paged", token_budget=10,
                  spec_decode=True, draft_k=4)
    _submit_all(eng, prompts, max_new=10)
    got = _drive(eng)
    assert eng.spec_decode and eng.scheduler is not None
    assert got == gold
    # Drafts really ran inside mixed windows.
    assert eng.stats["spec"]["proposed"] > 0


def test_mixed_identity_adversarial_arrival():
    """Requests trickle in while the engine is mid-flight — admission order
    and chunk interleavings differ wildly from batch submission, output must
    not."""
    prompts = _prompts(seed=7, n=6, lo=4, hi=30)
    ref = _engine(cache_mode="paged")
    _submit_all(ref, prompts)
    gold = _drive(ref)

    eng = _engine(cache_mode="paged", token_budget=8)
    it = iter(enumerate(prompts))
    uid, p = next(it)
    eng.submit(engine_lib.Request(uid=uid, prompt=p, max_new_tokens=8))
    pending = list(it)
    steps = 0
    while eng.queue or any(r is not None for r in eng.slot_req) or pending:
        if pending and steps % 2 == 0:
            uid, p = pending.pop(0)
            eng.submit(engine_lib.Request(uid=uid, prompt=p, max_new_tokens=8))
        eng.step()
        eng.audit()
        steps += 1
        assert steps < 500
    got = {r.uid: list(r.generated) for r in eng.finished}
    assert got == gold


# ---------------------------------------------------------------------------
# The stall gate: long prompt admitted mid-decode never pauses decode
# ---------------------------------------------------------------------------


def test_long_prompt_admission_zero_decode_stall():
    rng = np.random.RandomState(11)
    short = np.tile(rng.randint(1, CFG.vocab_size, 4), 3).astype(np.int32)
    long_p = rng.randint(1, CFG.vocab_size, 60).astype(np.int32)

    eng = _engine(slots=2, cache_mode="paged", token_budget=8)
    assert eng.submit(engine_lib.Request(uid=0, prompt=short, max_new_tokens=24))
    for _ in range(3):
        eng.step()
        eng.audit()
    tokens_before = len(eng.finished[0].generated) if eng.finished else len(
        next(r for r in eng.slot_req if r is not None).generated
    )
    # Admit a prompt ~8x the per-step budget mid-decode: it must stream in
    # over many steps while slot 0 keeps emitting every single step.
    assert eng.submit(engine_lib.Request(uid=1, prompt=long_p, max_new_tokens=4))
    got = _drive(eng)
    c = eng.stats["continuous"]
    assert c["decode_stall_steps"] == 0
    assert c["completed_prefills"] == 2
    assert c["prefill_tokens"] >= len(long_p)
    assert tokens_before < len(got[0])

    ref = _engine(slots=2, cache_mode="paged")
    ref.submit(engine_lib.Request(uid=0, prompt=short, max_new_tokens=24))
    ref.submit(engine_lib.Request(uid=1, prompt=long_p, max_new_tokens=4))
    assert _drive(ref) == got


# ---------------------------------------------------------------------------
# Scheduler policy: SLO classes, aging, preemption ordering
# ---------------------------------------------------------------------------


def test_slo_admission_order_and_aging():
    sched = engine_lib.TokenBudgetScheduler(16, aging_steps=4)
    inter = engine_lib.Request(uid=0, prompt=np.ones(2, np.int32),
                               max_new_tokens=1, slo_class="interactive")
    batch = engine_lib.Request(uid=1, prompt=np.ones(2, np.int32),
                               max_new_tokens=1, slo_class="batch")
    inter.enqueued_step = 6
    batch.enqueued_step = 0
    # Fresh interactive outranks batch (even one that has already aged a
    # class: batch waited 6-7 steps here -> one class up, still behind)...
    assert sched.queue_key(inter, 6) < sched.queue_key(batch, 6)
    assert sched.queue_key(inter, 7) < sched.queue_key(batch, 7)
    # ...until the batch request has aged 2 classes (8 steps): queued long
    # enough, it overtakes even interactive — starvation-free.
    assert sched.queue_key(batch, 8) < sched.queue_key(inter, 8)
    # Unknown classes rank as standard, never crash.
    odd = engine_lib.Request(uid=2, prompt=np.ones(2, np.int32),
                             max_new_tokens=1, slo_class="mystery")
    assert sched.rank(odd) == engine_lib.SLO_CLASSES["standard"]


def test_slo_admission_integration():
    """With one free slot, a later-submitted interactive request is admitted
    before an earlier batch one."""
    prompts = _prompts(seed=5, n=3, lo=4, hi=8)
    eng = _engine(slots=1, cache_mode="paged", token_budget=8)
    eng.submit(engine_lib.Request(uid=0, prompt=prompts[0], max_new_tokens=4,
                                  slo_class="batch"))
    eng.submit(engine_lib.Request(uid=1, prompt=prompts[1], max_new_tokens=4,
                                  slo_class="batch"))
    eng.submit(engine_lib.Request(uid=2, prompt=prompts[2], max_new_tokens=4,
                                  slo_class="interactive"))
    _drive(eng)
    order = [r.uid for r in eng.finished]
    assert order.index(2) < order.index(1)


def test_slo_preemption_victim_ordering():
    """Preemption evicts by SLO class before admission ticket: a batch row
    admitted EARLIER (older ticket) is still evicted before an interactive
    row — the phase-split rule (latest ticket) would pick the interactive
    one."""
    prompts = _prompts(seed=6, n=2, lo=4, hi=6)
    eng = _engine(slots=2, cache_mode="paged", token_budget=8)
    eng.submit(engine_lib.Request(uid=0, prompt=prompts[0], max_new_tokens=30,
                                  slo_class="batch"))
    eng.step()  # batch admitted first -> earliest ticket
    eng.submit(engine_lib.Request(uid=1, prompt=prompts[1], max_new_tokens=30,
                                  slo_class="interactive"))
    eng.step()
    slots_by_uid = {eng.slot_req[s].uid: s for s in range(2) if eng.slot_req[s]}
    assert set(slots_by_uid) == {0, 1}
    victims = list(slots_by_uid.values())
    victim = max(victims, key=eng._victim_key)
    assert victim == slots_by_uid[0]  # the batch row, despite its older ticket
    # Phase-split engines keep the pure-ticket rule.
    ref = _engine(slots=2, cache_mode="paged")
    assert ref._victim_key(0) == ref.slot_ticket[0]


def test_budget_floor_makes_progress():
    """A budget smaller than the active row count cannot livelock: decode
    rows keep their 1-token floor and every prefill row still gets >= 1
    chunk token per step."""
    sched = engine_lib.TokenBudgetScheduler(2)
    chunks = sched.split_chunks(4, {7: 10, 8: 1, 9: 3}, [7, 8, 9])
    assert chunks == {7: 1, 8: 1, 9: 1}
    prompts = _prompts(seed=9, n=4, lo=8, hi=20)
    eng = _engine(slots=3, cache_mode="paged", token_budget=1)
    _submit_all(eng, prompts, max_new=4)
    gold_eng = _engine(slots=3, cache_mode="paged")
    _submit_all(gold_eng, prompts, max_new=4)
    assert _drive(eng, budget=600) == _drive(gold_eng)


def test_draft_budget_split():
    # No budget: full draft_k stands (phase-split engines).
    assert spec_lib.draft_budget(4, 3, None) == 4
    # Decode floor reserved first, spare split evenly.
    assert spec_lib.draft_budget(4, 3, 9) == 2
    # Budget at the floor: no drafts, decode still proceeds.
    assert spec_lib.draft_budget(4, 3, 3) == 0
    assert spec_lib.draft_budget(4, 3, 2) == 0
    # Clamped to draft_k.
    assert spec_lib.draft_budget(2, 1, 100) == 2


def test_token_budget_degrades_like_spec():
    """Configurations that cannot run a verify window run phase-split (the
    spec_decode degrade convention), never a broken mixed path."""
    eng = _engine(decode_mode="grouped", token_budget=16)
    assert eng.scheduler is None and eng.token_budget is None
    prompts = _prompts(seed=2, n=2)
    _submit_all(eng, prompts, max_new=4)
    ref = _engine(decode_mode="grouped")
    _submit_all(ref, prompts, max_new=4)
    assert _drive(eng) == _drive(ref)


# ---------------------------------------------------------------------------
# Satellite 1: queued-request deadline race at admission time
# ---------------------------------------------------------------------------


class _ScriptedClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _lapse_after_reap(eng, jump_s):
    """Arm the deadline race: advance the engine clock right AFTER the reap
    sweep runs, so a queued deadline lapses between the sweep's snapshot
    and the same step's admission — the exact window the admission-time
    re-check exists for."""
    orig = eng._reap_lifecycle

    def reap_then_lapse():
        orig()
        # Fire only when admission can actually run (a slot is free) — the
        # lapse then lands squarely between sweep and admission; earlier
        # steps would just hand the reap to the NEXT sweep.
        if eng.queue and any(r is None for r in eng.slot_req):
            eng.clock.t += jump_s

    eng._reap_lifecycle = reap_then_lapse


@pytest.mark.parametrize("budget_mode", [False, True])
def test_deadline_lapse_between_reap_and_admission(budget_mode):
    """A queued request whose deadline lapses after the reap sweep but
    before admission in the SAME step must finish "expired" without ever
    occupying a slot — the pre-fix engine admitted it, burned a prefill
    (and, paged, committed pool pages to a corpse), and only reaped it a
    full step later."""
    clock = _ScriptedClock()
    prompts = _prompts(seed=4, n=2, lo=4, hi=6)
    kw = dict(token_budget=8) if budget_mode else {}
    eng = _engine(slots=1, cache_mode="paged", clock=clock, **kw)
    eng.submit(engine_lib.Request(uid=0, prompt=prompts[0], max_new_tokens=6))
    eng.step()
    eng.submit(engine_lib.Request(uid=1, prompt=prompts[1], max_new_tokens=6,
                                  deadline_ms=500.0))
    _lapse_after_reap(eng, jump_s=600.0)
    _drive(eng)
    by_uid = {r.uid: r for r in eng.finished}
    assert by_uid[0].status == "ok"
    assert by_uid[1].status == "expired"
    # The expired request never ran: no tokens, never occupied a slot.
    assert by_uid[1].generated == []
    assert by_uid[1].error and "at admission" in by_uid[1].error


@pytest.mark.parametrize("budget_mode", [False, True])
def test_cancel_between_reap_and_admission(budget_mode):
    """Same race window, cancel flavour: a cancel landing after the sweep
    is honoured at admission, not a step later."""
    prompts = _prompts(seed=8, n=2, lo=4, hi=6)
    kw = dict(token_budget=8) if budget_mode else {}
    eng = _engine(slots=1, cache_mode="paged", **kw)
    eng.submit(engine_lib.Request(uid=0, prompt=prompts[0], max_new_tokens=6))
    victim = engine_lib.Request(uid=1, prompt=prompts[1], max_new_tokens=6)
    eng.step()
    eng.submit(victim)
    orig = eng._reap_lifecycle

    def reap_then_cancel():
        orig()
        if victim in eng.queue and any(r is None for r in eng.slot_req):
            victim.cancel()

    eng._reap_lifecycle = reap_then_cancel
    _drive(eng)
    by_uid = {r.uid: r for r in eng.finished}
    assert by_uid[1].status == "cancelled"
    assert by_uid[1].generated == []


# ---------------------------------------------------------------------------
# Satellite 2: chunk boundary x paged prefix reuse (COW at a partial block)
# ---------------------------------------------------------------------------


def _prefix_pair(bs):
    """Two prompts sharing a prefix whose length (2.5 blocks) is NOT a
    multiple of the block size or any chunk split — the partial boundary
    block must COW-split, never re-scatter onto the shared page."""
    rng = np.random.RandomState(13)
    prefix = rng.randint(1, CFG.vocab_size, 2 * bs + bs // 2).astype(np.int32)
    p0 = np.concatenate([prefix, rng.randint(1, CFG.vocab_size, 7).astype(np.int32)])
    p1 = np.concatenate([prefix, rng.randint(1, CFG.vocab_size, 9).astype(np.int32)])
    return p0, p1


def test_chunked_prefill_shared_prefix_partial_boundary_block():
    """The second prompt admits while the first (fully prefilled) is still
    resident: its chunks must RESUME at the shared-page boundary — reusing
    both full prefix pages verbatim, COW-splitting the partial boundary
    block — and never rewrite a shared page.  BlockAllocator.audit()
    (refcount-exact) runs every step; token identity closes the loop."""
    bs = 8
    p0, p1 = _prefix_pair(bs)
    ref = _engine(slots=2, cache_mode="paged", block_size=bs)
    _submit_all(ref, [p0, p1], max_new=8)
    gold = _drive(ref)

    eng = _engine(slots=2, cache_mode="paged", block_size=bs, token_budget=6)
    eng.submit(engine_lib.Request(uid=0, prompt=p0, max_new_tokens=8))
    steps = 0
    while int(eng.slot_prefill_done[0]) < len(p0):
        eng.step()
        eng.audit()
        steps += 1
        assert steps < 50
    eng.submit(engine_lib.Request(uid=1, prompt=p1, max_new_tokens=8))
    eng.step()
    eng.audit()
    s1 = next(s for s in range(2)
              if eng.slot_req[s] is not None and eng.slot_req[s].uid == 1)
    # uid 1's chunks resumed at the shared boundary (2 full blocks = 16
    # tokens) — a from-scratch prefill could have covered at most the
    # budget's worth by now.
    assert int(eng.slot_prefill_done[s1]) >= 2 * bs
    got = _drive(eng)
    assert got == gold
    st = eng.stats
    assert st["shared_hits"] >= 2   # both full prefix blocks reused
    assert st["cow_events"] >= 1    # the partial boundary block was split
    assert st["pages_in_use"] == 0  # drained clean: no leak, no double-free


def test_chunked_prefill_shared_prefix_unwritten_pages():
    """Both prefix-sharing prompts arrive together, but the second's
    matching radix-tree pages hold NO content yet (commit_prompt registers
    before chunks write).  A row prefilling from inside an unwritten shared
    block would read garbage history, so admission must not take the share
    early — it DEFERS the second admission until the writer's chunks cover
    the shared prefix, then re-plans into a REAL share (surfaced as
    deferred_hits).  Output is unchanged; no phantom sharing before the
    content lands."""
    bs = 8
    p0, p1 = _prefix_pair(bs)
    ref = _engine(slots=2, cache_mode="paged", block_size=bs)
    _submit_all(ref, [p0, p1], max_new=6)
    gold = _drive(ref)

    eng = _engine(slots=2, cache_mode="paged", block_size=bs, token_budget=6)
    _submit_all(eng, [p0, p1], max_new=6)
    eng.step()  # uid 0 admits; uid 1 defers on the unwritten prefix
    eng.audit()
    assert int(eng.slot_prefill_done.max()) <= 6  # nobody skipped ahead
    assert _drive(eng) == gold
    st = eng.stats
    assert st["prefix_cache"]["deferred_hits"] > 0  # share recovered, not lost
    assert st["shared_hits"] >= 2   # both full prefix blocks reused post-defer
    assert st["pages_in_use"] == 0


# ---------------------------------------------------------------------------
# Satellite 3: spec accounting when EOS lands mid-draft-window
# ---------------------------------------------------------------------------


def _continuation(prompt, n):
    """The model's greedy continuation (via a phase-split reference run)."""
    eng = _engine(slots=1, cache_mode="paged")
    eng.submit(engine_lib.Request(uid=0, prompt=prompt, max_new_tokens=n))
    return _drive(eng)[0]


@pytest.mark.parametrize("budget_mode", [False, True])
def test_spec_accounting_eos_mid_draft_window(budget_mode):
    prompt = _prompts(seed=23, n=1, lo=6, hi=7)[0]
    cont = _continuation(prompt, 8)
    eos = cont[1]
    if eos in cont[:1]:
        pytest.skip("degenerate continuation: EOS would fire before window")

    def oracle_drafter(ctx, k):
        # Proposes the true continuation: every draft token is accepted, so
        # the EOS at continuation index 1 truncates the commit mid-window.
        done = len(ctx) - len(prompt)
        return np.asarray(cont[done : done + k], np.int32)

    kw = dict(token_budget=12) if budget_mode else {}
    eng = _engine(slots=1, cache_mode="paged", spec_decode=True, draft_k=4,
                  drafter=oracle_drafter, **kw)
    eng.submit(engine_lib.Request(uid=0, prompt=prompt, max_new_tokens=8,
                                  eos_id=int(eos)))
    got = _drive(eng)
    assert got[0] == cont[:2]  # truncated at the EOS draft
    st = eng.stats["spec"]
    req = eng.finished[-1]
    # Only the consumed draft tokens count — the scored-but-dead tail is
    # excluded.  Pre-fix: proposed counted the full window here, deflating
    # acceptance_rate on a window that was 100% accepted.  In budget mode
    # cont[0] is the prefill-completion bonus (not spec-counted), so the
    # decode window consumes exactly the one EOS draft; phase-split spec
    # consumes both.
    expected = 1 if budget_mode else 2
    assert req.draft_proposed == req.draft_accepted == expected
    assert st["proposed"] == st["accepted"] == expected
    assert st["committed"] == expected
    assert st["acceptance_rate"] == 1.0


# ---------------------------------------------------------------------------
# Streaming + registry routing
# ---------------------------------------------------------------------------


def test_stream_cb_sees_every_token_in_order():
    prompts = _prompts(seed=15, n=3)
    seen: dict[int, list[int]] = {}

    def cb(req, tok):
        seen.setdefault(req.uid, []).append(tok)

    eng = _engine(cache_mode="paged", token_budget=8, stream_cb=cb)
    _submit_all(eng, prompts, max_new=6)
    got = _drive(eng)
    assert seen == got


def test_mixed_dispatch_key_hits_gemm_bucket():
    """A wide mixed window (slots x L past 64 rows) must key the "big"
    M-bucket, which the registry routes to the packed mmt4d GEMM — the
    fused GEMV fall-through was the mixed-M routing bug."""
    eng = _engine(slots=3, cache_mode="paged", token_budget=40)
    eng._mixed_m = 3 * 32
    _attn_key, mm_key = eng._dispatch_keys("mixed")
    assert "|big|" in mm_key
    assert registry_lib.resolve_key(mm_key).backend != "fused"


def test_deferred_hit_recovers_unwritten_prefix():
    """A request whose tree-matched prefix is still being WRITTEN by an
    in-flight chunked prefill defers instead of forfeiting the reuse: it
    re-checks the tree at its next admission opportunity, admits off the
    now-written blocks once the writer's chunks commit, and the recovered
    blocks are counted in stats["prefix_cache"]["deferred_hits"] — all
    without perturbing the generated tokens."""
    rng = np.random.RandomState(3)
    prompt = rng.randint(1, CFG.vocab_size, 33).astype(np.int32)
    mk = dict(cache_mode="paged", block_size=8, token_budget=8, slots=2)

    gold_eng = _engine(**mk)
    assert gold_eng.submit(engine_lib.Request(
        uid=0, prompt=prompt, max_new_tokens=6))
    gold = _drive(gold_eng)[0]

    eng = _engine(**mk)
    for uid in (0, 1):  # identical prompts: uid 1 races uid 0's prefill
        assert eng.submit(engine_lib.Request(
            uid=uid, prompt=prompt.copy(), max_new_tokens=6))
    out = _drive(eng)
    assert out[0] == gold and out[1] == gold
    pc = eng.stats["prefix_cache"]
    assert pc["deferred_hits"] > 0, "unwritten-prefix share was forfeited"
    assert pc["hit_blocks"] >= pc["deferred_hits"]
