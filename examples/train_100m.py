"""Train a ~100M-parameter Llama-style model with the full production stack:
mmt4d-encoded weights, AdamW, grad clipping, async checkpointing, straggler
watchdog, deterministic packed data.

~100M params is slow on this 1-core CPU container; default is 60 steps
(--steps 300 for the full run).  Loss is printed every 10 steps and must
decrease.

  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch import train as train_lib

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
args = ap.parse_args()

# ~100M-class config: 8 layers x d=768 x ff=3072, 32k vocab ≈ 106M params.
sys.argv = [
    "train", "--arch", "llama3.2-1b",
    "--layers", "8", "--d-model", "768", "--d-ff", "3072", "--vocab", "32768",
    "--steps", str(args.steps), "--batch", "8", "--seq", "256",
    "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "20",
    "--log-every", "10",
]
train_lib.main()
