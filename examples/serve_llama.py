"""End-to-end serving driver (the paper's workload kind): continuous-batching
engine over a reduced Llama-3.2-1B with the mmt4d serving path —
prefill GEMM kernels, decode GEMV kernels, slot-based admission, and the
block-paged KV cache (prefix reuse + preemption; --cache-mode dense for the
worst-case-reservation baseline).

  PYTHONPATH=src python examples/serve_llama.py [--requests 12]
  PYTHONPATH=src python examples/serve_llama.py --cache-mode paged \
      --block-size 8 --pool-pages 24   # force pool pressure -> preemption
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.core import encoding
from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.serving import engine as engine_lib

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=12)
ap.add_argument("--slots", type=int, default=4)
ap.add_argument("--max-new", type=int, default=12)
ap.add_argument("--cache-mode", choices=("paged", "dense"), default="paged")
ap.add_argument("--block-size", type=int, default=16)
ap.add_argument("--pool-pages", type=int, default=None,
                help="paged pool size; small values force preemption")
ap.add_argument("--attn-backend", choices=("auto", "pallas", "xla"),
                default="auto",
                help="attention op-class backend (kernels/registry.py "
                     "select_attn): pallas = fused paged-decode / flash "
                     "prefill microkernels (kernels/attn.py), xla = the jnp "
                     "references, auto = tuned table -> static policy")
ap.add_argument("--quant", choices=("none", "w8a8", "w4a8"), default="none",
                help="serving weight format: w8a8 = int8 per-channel, "
                     "w4a8 = group int4 (kernels/mmt4d_q4.py)")
ap.add_argument("--quant-group", type=int, default=16,
                help="w4a8 K-group size (16 default; 32 = llama.cpp Q4_0)")
ap.add_argument("--spec-decode", action="store_true",
                help="speculative decode: prompt-lookup drafts + one batched "
                     "verify dispatch per step (greedy only; serving/spec.py)")
ap.add_argument("--draft-k", type=int, default=4,
                help="max draft tokens proposed per slot per verify step")
ap.add_argument("--sample", choices=("greedy", "temperature"), default="greedy",
                help="temperature: per-slot temperature sampling (PRNG "
                     "threaded per step; disables --spec-decode)")
ap.add_argument("--temperature", type=float, default=0.8,
                help="per-request sampling temperature (--sample temperature)")
ap.add_argument("--eos-id", type=int, default=None,
                help="stop token: slots finish early when they emit it")
ap.add_argument("--deadline-ms", type=float, default=None,
                help="per-request wall-clock budget (submit -> last token); "
                     "expired requests finish with status 'expired', keeping "
                     "what they generated (docs/ROBUSTNESS.md)")
ap.add_argument("--max-queue", type=int, default=None,
                help="admission-queue bound: past it submit() returns a "
                     "structured Rejected('queue_full') instead of growing "
                     "the queue without bound")
args = ap.parse_args()

cfg = registry.get_reduced("llama3.2-1b")
WEIGHT_QUANT = {"none": "none", "w8a8": "int8", "w4a8": "int4"}[args.quant]
enc = EncodingConfig(
    enabled=True, backend="xla", attn_backend=args.attn_backend,
    weight_quant=WEIGHT_QUANT, quant_group=args.quant_group,
)
params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
eng = engine_lib.Engine(
    params, cfg, enc, slots=args.slots, max_seq=96,
    cache_mode=args.cache_mode, block_size=args.block_size,
    pool_pages=args.pool_pages,
    sample=args.sample, spec_decode=args.spec_decode, draft_k=args.draft_k,
    max_queue=args.max_queue,
)

rng = np.random.RandomState(0)
arrival = 0.0
t0 = time.time()
rejections = []
for i in range(args.requests):
    plen = rng.randint(4, 20)
    prompt = rng.randint(1, cfg.vocab_size, plen).astype(np.int32)
    if args.spec_decode and i % 2 == 0:
        prompt = np.tile(prompt[:4], 4)  # repetition-heavy cohort: drafts hit
    res = eng.submit(engine_lib.Request(
        uid=i, prompt=prompt, max_new_tokens=args.max_new,
        eos_id=args.eos_id, temperature=args.temperature,
        deadline_ms=args.deadline_ms,
    ))
    if not res:
        rejections.append(res)
        print(f"  rejected uid={res.uid} ({res.reason}): {res.detail}")

steps = 0
while eng.queue or any(r is not None for r in eng.slot_req):
    eng.step()
    steps += 1
dt = time.time() - t0
total = sum(len(r.generated) for r in eng.finished)
print(f"served {len(eng.finished)} requests / {total} tokens "
      f"in {dt:.2f}s over {steps} engine steps ({total/dt:.2f} tok/s)")
stats = eng.stats
ATTN_NOTE = {
    "pallas": "decode streamed only each slot's live KV pages — no "
              "paged_gather materialization (kernels/attn.py)",
    "xla": "decode ran the jnp reference path (gather-materializing fallback)",
}
print(f"  attn_backend={stats['attn_backend']} (requested "
      f"{args.attn_backend}): {ATTN_NOTE[stats['attn_backend']]}")
if args.quant != "none":
    # Decode weight-stream roofline: aggregate projection bytes per token at
    # this quant mode vs bf16 (encoding.quant_weight_stream_bytes; the scale
    # term aggregates exactly because every projection K divides the group).
    p = cfg.param_count()
    wq = encoding.quant_weight_stream_bytes(
        1, p, quant=args.quant, group=args.quant_group
    )
    wfp = encoding.quant_weight_stream_bytes(1, p, quant="none")
    print(f"  quant={args.quant} (group={args.quant_group}): "
          f"{wq / p:.3f} bytes/weight streamed per decode token "
          f"({wfp / wq:.2f}x less than bf16 -> projected tok/s uplift)")
if eng.spec_decode:
    sp = stats["spec"]
    print(f"  spec: draft_k={stats['draft_k']} "
          f"accepted={sp['accepted']}/{sp['proposed']} "
          f"(rate {sp['acceptance_rate']:.2f}) "
          f"mean_accepted_len={sp['mean_accepted_len']:.2f} "
          f"-> ~{sp['mean_accepted_len']:.2f}x fewer decode dispatches/token")
if stats["cache_mode"] == "paged":
    print(f"  paged: peak_active={stats['peak_active']} "
          f"pages={stats['pages_total']} peak_in_use={stats['peak_in_use']} "
          f"shared_hits={stats['shared_hits']} cow={stats['cow_events']} "
          f"preemptions={stats['preemptions']}")
wd = stats["watchdog"]
print(f"  watchdog: p50={wd['p50_ms']:.1f}ms p99={wd['p99_ms']:.1f}ms "
      f"ewma={wd['ewma_ms']:.1f}ms stalls={wd['stalls']}")
life = stats["lifecycle"]
outcomes = {s: sum(1 for r in eng.finished if r.status == s)
            for s in engine_lib.REQUEST_STATUSES}
print("  lifecycle: "
      + " ".join(f"{k}={v}" for k, v in outcomes.items() if v)
      + (f" rejected={life['rejected']}" if life["rejected"] else ""))
if stats["degraded"]:
    for d in stats["degraded"]:
        print(f"  DEGRADED {d['key']}: {d['from']} -> {d['to']} "
              f"(step {d['step']}, {d['reason']})")
for r in eng.finished[:5]:
    print(f"  req {r.uid}: |prompt|={len(r.prompt)} status={r.status} "
          f"gen={r.generated}")
