"""Quickstart: the paper's pipeline end to end on a tiny model.

  1. build a reduced Llama-3.2-1B (the paper's model family),
  2. run the same weights through the reference path and the mmt4d path and
     check parity (paper Table 1),
  3. train a few steps (encoded path is fully differentiable),
  4. greedy-decode a few tokens through prefill+decode phase kernels.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.data import pipeline as data_lib
from repro.models import transformer as T
from repro.train import optimizer as opt_lib
from repro.train import trainer as trainer_lib

cfg = registry.get_reduced("llama3.2-1b")
print(f"model: {cfg.name}  layers={cfg.num_layers} d={cfg.d_model} "
      f"params~{cfg.param_count()/1e6:.2f}M")

# -- 1+2: parity between reference and encoded paths --------------------------
enc_ref = EncodingConfig(enabled=False, backend="reference")
enc_mmt = EncodingConfig(enabled=True, backend="xla")
p_ref = T.model_init(jax.random.PRNGKey(0), cfg, enc_ref)
p_mmt = T.model_init(jax.random.PRNGKey(0), cfg, enc_mmt)
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 1, cfg.vocab_size)
l_ref, _, _ = T.forward(p_ref, {"tokens": toks}, cfg=cfg, enc=enc_ref, phase=Phase.PREFILL)
l_mmt, _, _ = T.forward(p_mmt, {"tokens": toks}, cfg=cfg, enc=enc_mmt, phase=Phase.PREFILL)
print(f"parity: max |dlogit| = {float(jnp.max(jnp.abs(l_ref - l_mmt))):.2e} "
      f"argmax agree = {bool((l_ref.argmax(-1) == l_mmt.argmax(-1)).all())}")

# -- 3: train a few steps on the encoded path --------------------------------
opt_cfg = opt_lib.OptimizerConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=50)
opt_state = opt_lib.init(p_mmt)
data = data_lib.SyntheticPacked(data_lib.DataConfig(cfg.vocab_size, 32, 8))
step = jax.jit(trainer_lib.make_train_step(cfg, enc_mmt, opt_cfg))
params = p_mmt
for i in range(10):
    params, opt_state, m, _ = step(params, opt_state, jax.tree.map(jnp.asarray, data.batch(i)))
    if i % 3 == 0:
        print(f"train step {i}: loss={float(m['loss']):.4f}")

# -- 4: greedy decode through the phase-split serving path -------------------
from repro.serving import engine as engine_lib
prefill = jax.jit(engine_lib.make_prefill_step(cfg, enc_mmt))
decode = jax.jit(engine_lib.make_decode_step(cfg, enc_mmt))
caches = T.cache_init(cfg, 1, max_seq=48)
prompt = toks[:1, :8]
_, caches = prefill(params, prompt, caches)
tok = prompt[:, -1:]
out = []
for i in range(8):
    tok, _, caches = decode(params, caches, tok, jnp.asarray(7 + i, jnp.int32))
    out.append(int(tok[0, 0]))
print("decoded:", out)
print("quickstart OK")
