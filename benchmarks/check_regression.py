"""Bench-regression gate: compare fresh --quick BENCH_*.json against the
committed baselines and exit nonzero on regression.

    PYTHONPATH=src python benchmarks/run.py --quick          # writes BENCH_*.json
    python benchmarks/check_regression.py                    # gates on them

Three check modes, chosen per metric:

  min_abs        fresh >= value.  Hard floors for invariants and for
                 deterministic model-derived ratios (e.g. the w4a8 decode
                 weight-stream win must stay >= 1.5x w8a8 — the PR's
                 acceptance bar, kept live in CI).
  max_abs        fresh <= value.  Dispatch-count ceilings.
  baseline_frac  fresh >= baseline_value * frac.  For metrics read from the
                 committed baseline file: frac ~0.99 for deterministic
                 quantities (traffic models, scheduler counters — same seeds,
                 same code, same numbers), a wide band (0.2) for wall-clock
                 throughputs so heterogeneous CI runners don't flap but an
                 artificially slowed tree still trips the gate.

Every failure prints a ``REGRESSION`` line; missing files/metrics are also
failures (a bench that silently stopped emitting a metric is a regression).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# (file, dotted.metric.path, mode, param)
CHECKS = [
    # -- decode fast path: dispatch + traffic invariants --
    ("BENCH_decode.json", "engine.vectorized.decode_calls_per_step", "max_abs", 1.0),
    ("BENCH_decode.json", "engine.vectorized_vs_grouped_speedup", "min_abs", 1.5),
    ("BENCH_decode.json", "op.hbm_savings_frac", "baseline_frac", 0.99),
    # -- quant ladder: the w4a8 acceptance bar (deterministic traffic model) --
    ("BENCH_decode.json", "quant.w4a8_vs_w8a8_model_tok_s_ratio", "min_abs", 1.5),
    ("BENCH_decode.json", "quant.w4a8_vs_bf16_model_tok_s_ratio", "baseline_frac", 0.99),
    # -- attention op class: the PR-5 acceptance bar.  The paged-decode
    #    kernel must keep streaming only live pages (fused <= 0.5x the
    #    gather-materialization baseline at 4k context — deterministic
    #    traffic model), with kernel parity (dense/paged vs jnp references
    #    + paged-vs-dense bit-consistency) holding exactly --
    ("BENCH_decode.json", "attn.paged_bytes_ratio_4k", "max_abs", 0.5),
    ("BENCH_decode.json", "attn.kernel_parity", "min_abs", 1.0),
    ("BENCH_decode.json", "attn.paged_vs_dense_bit_consistent", "min_abs", 1.0),
    ("BENCH_decode.json", "attn.attn_weight_crossover_tokens", "baseline_frac", 0.99),
    # -- speculative decode: the PR-4 acceptance bar (measured dispatch
    #    counts on the repetition-heavy workload; greedy output must stay
    #    token-identical to plain decode) --
    ("BENCH_decode.json", "spec.dispatches_per_token", "max_abs", 0.5),
    ("BENCH_decode.json", "spec.mean_accepted_len", "min_abs", 1.05),
    ("BENCH_decode.json", "spec.token_identical", "min_abs", 1.0),
    # -- chaos conformance (docs/ROBUSTNESS.md): under the committed
    #    adversarial fault schedule, survivors stay token-identical to the
    #    fault-free run and every lifecycle exit path frees its pages --
    ("BENCH_decode.json", "chaos.token_identical_under_faults", "min_abs", 1.0),
    ("BENCH_decode.json", "chaos.pages_leaked", "max_abs", 0.0),
    # -- continuous batching: the token-budget acceptance bar.  A long
    #    prompt admitted mid-decode costs ZERO decode-stall steps (the
    #    1-token-per-decode-row budget floor), stays token-identical to the
    #    phase-split engine, and leaks nothing --
    ("BENCH_decode.json", "continuous.decode_stall_steps", "max_abs", 0.0),
    ("BENCH_decode.json", "continuous.token_identical", "min_abs", 1.0),
    ("BENCH_decode.json", "continuous.pages_leaked", "max_abs", 0.0),
    # -- tensor parallelism: the TP acceptance bar.  mesh=2/4 decode must be
    #    token-identical to mesh=1 (measured on 4 emulated CPU devices), and
    #    head-parallel KV must scale paged capacity >= 1.8x at 2 shards under
    #    a fixed per-shard HBM budget (deterministic capacity model) --
    ("BENCH_decode.json", "tp.token_identical", "min_abs", 1.0),
    ("BENCH_decode.json", "tp.kv_capacity_scaling_2", "min_abs", 1.8),
    ("BENCH_decode.json", "tp.kv_capacity_scaling_4", "baseline_frac", 0.99),
    # -- quantized KV cache: the kv8 acceptance bar.  kv8 must never flip a
    #    confident (margin >= median) decision on the seeded stream, pool
    #    capacity under one HBM budget must scale >= 1.8x vs bf16, and fused
    #    paged-decode traffic at 4k context must stay <= 0.6x bf16
    #    (per-page scales included) --
    ("BENCH_decode.json", "kv8.token_identical_confident", "min_abs", 1.0),
    ("BENCH_decode.json", "kv8.kv_capacity_scaling", "min_abs", 1.8),
    ("BENCH_decode.json", "kv8.paged_bytes_ratio_vs_bf16_4k", "max_abs", 0.6),
    # -- wall clock, wide band (catches artificial slowdowns, not runner skew) --
    ("BENCH_decode.json", "engine.vectorized.tok_s", "baseline_frac", 0.2),
    # -- paged KV cache: deterministic scheduler outcomes (seeded stream) --
    ("BENCH_paged.json", "concurrent_requests.paged_vs_dense_ratio", "baseline_frac", 0.99),
    ("BENCH_paged.json", "paged.shared_hits", "baseline_frac", 0.99),
    ("BENCH_paged.json", "paged.pool_utilization_peak", "baseline_frac", 0.99),
    ("BENCH_paged.json", "paged.tok_s", "baseline_frac", 0.2),
    # -- radix-tree prefix cache: the multi-tenant trace acceptance bar.
    #    Block-level LCP hit rate must not regress (and the committed
    #    baseline itself clears 0.5 where the old exact-whole-prefix
    #    matcher scores < 0.1), outputs must be token-identical cache
    #    on/off/dense, and the eviction-pressure leg must drain leak-free --
    ("BENCH_paged.json", "prefix_cache.hit_rate", "baseline_frac", 0.99),
    ("BENCH_paged.json", "prefix_cache.token_identical", "min_abs", 1.0),
    ("BENCH_paged.json", "prefix_cache.pages_leaked", "max_abs", 0.0),
    ("BENCH_paged.json", "prefix_cache.quota_violations", "max_abs", 0.0),
]


def _lookup(tree: dict, dotted: str):
    node = tree
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node if isinstance(node, (int, float)) else None


def _load(path: str) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def check(fresh_dir: str, baseline_dir: str) -> int:
    failures = 0
    fresh_cache: dict[str, dict | None] = {}
    base_cache: dict[str, dict | None] = {}
    for fname, metric, mode, param in CHECKS:
        if fname not in fresh_cache:
            fresh_cache[fname] = _load(os.path.join(fresh_dir, fname))
            base_cache[fname] = _load(os.path.join(baseline_dir, fname))
        fresh, base = fresh_cache[fname], base_cache[fname]
        if fresh is None:
            print(f"REGRESSION {fname}: missing/unreadable fresh file")
            failures += 1
            continue
        got = _lookup(fresh, metric)
        if got is None:
            print(f"REGRESSION {fname}:{metric}: metric missing from fresh run")
            failures += 1
            continue
        if mode == "min_abs":
            ok, floor = got >= param, param
        elif mode == "max_abs":
            ok, floor = got <= param, param
        else:  # baseline_frac
            if base is None:
                print(f"REGRESSION {fname}: missing baseline (commit one under "
                      f"{baseline_dir}/)")
                failures += 1
                continue
            want = _lookup(base, metric)
            if want is None:
                print(f"REGRESSION {fname}:{metric}: metric missing from baseline")
                failures += 1
                continue
            floor = want * param
            ok = got >= floor
        status = "ok" if ok else "REGRESSION"
        print(f"{status} {fname}:{metric} = {got:.4f} ({mode} bound {floor:.4f})")
        if not ok:
            failures += 1
    if failures:
        print(f"check_regression: {failures} failing check(s)")
        return 1
    print("check_regression: all checks passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default=".",
                    help="where run.py --quick wrote BENCH_*.json")
    ap.add_argument(
        "--baseline-dir",
        default=os.path.join(os.path.dirname(__file__), "baselines"),
        help="committed baseline BENCH_*.json directory",
    )
    args = ap.parse_args()
    return check(args.fresh_dir, args.baseline_dir)


if __name__ == "__main__":
    sys.exit(main())
