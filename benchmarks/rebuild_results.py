"""Recompute results/dryrun/*.json roofline inputs from the saved HLO dumps
(results/hlo/*.hlo.txt.gz) with the current analyzer — no recompilation."""

from __future__ import annotations

import glob
import gzip
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import hlo_analysis as H  # noqa: E402


def main(result_dir="results/dryrun", hlo_dir="results/hlo"):
    n = 0
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "skipped" in rec:
            continue
        tag = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        hpath = os.path.join(hlo_dir, tag + ".hlo.txt.gz")
        if not os.path.exists(hpath):
            print(f"[warn] no HLO for {tag}")
            continue
        with gzip.open(hpath, "rt") as f:
            hlo = f.read()
        a = H.analyze(hlo)
        rec["flops_per_device"] = a["flops"]
        rec["bytes_per_device"] = a["hbm_bytes"]
        rec["bytes_per_device_unfused"] = a["hbm_bytes_unfused"]
        rec["collective_bytes_per_device"] = a["collective_bytes"]
        rec["collective_ops"] = a["collective_counts"]
        rec["collective_per_op"] = a["collective_per_op"]
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"rebuilt {n} records")


if __name__ == "__main__":
    main()
