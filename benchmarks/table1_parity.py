"""Table 1 analog: accuracy parity between the reference model and the
mmt4d-encoded model.

The paper validates its microkernels by scoring Llama-3.2-1B on ARC-c/GPQA
with LM-Evaluation-Harness and requiring identical scores vs HuggingFace.
Offline analog: a synthetic multiple-choice suite scored by per-option
log-likelihood (exactly the lm-eval-harness protocol), run through (a) the
un-encoded reference path and (b) the packed mmt4d path — same weights.
Deliverable: identical accuracies and argmax decisions; max |Δlogit| reported.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.models import transformer as T


def _score_options(params, cfg, enc, prompts, options):
    """Log-likelihood of each option continuation given the prompt."""
    scores = []
    fwd = jax.jit(
        lambda p, t: T.forward(p, {"tokens": t}, cfg=cfg, enc=enc, phase=Phase.PREFILL)[0]
    )
    for prompt, opts in zip(prompts, options):
        row = []
        for opt in opts:
            toks = jnp.asarray(np.concatenate([prompt, opt])[None], jnp.int32)
            logits = fwd(params, toks)
            lp = jax.nn.log_softmax(logits[0, :-1], axis=-1)
            idx = toks[0, 1:]
            tail = len(opt)
            ll = float(
                jnp.take_along_axis(lp[-tail:], idx[-tail:, None], axis=-1).sum()
            )
            row.append(ll)
        scores.append(row)
    return np.asarray(scores)


def run(n_questions: int = 12, n_options: int = 4, seed: int = 0, arch: str = "llama3.2-1b"):
    cfg = registry.get_reduced(arch)
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(1, cfg.vocab_size, rng.randint(6, 12)).astype(np.int32)
               for _ in range(n_questions)]
    options = [
        [rng.randint(1, cfg.vocab_size, rng.randint(2, 5)).astype(np.int32)
         for _ in range(n_options)]
        for _ in range(n_questions)
    ]
    answers = rng.randint(0, n_options, n_questions)  # synthetic "gold" labels

    enc_ref = EncodingConfig(enabled=False, backend="reference")
    enc_mmt = EncodingConfig(enabled=True, backend="xla")
    params_ref = T.model_init(jax.random.PRNGKey(seed), cfg, enc_ref)
    params_mmt = T.model_init(jax.random.PRNGKey(seed), cfg, enc_mmt)

    t0 = time.time()
    s_ref = _score_options(params_ref, cfg, enc_ref, prompts, options)
    s_mmt = _score_options(params_mmt, cfg, enc_mmt, prompts, options)

    acc_ref = float(np.mean(s_ref.argmax(1) == answers))
    acc_mmt = float(np.mean(s_mmt.argmax(1) == answers))
    agree = float(np.mean(s_ref.argmax(1) == s_mmt.argmax(1)))
    max_dll = float(np.max(np.abs(s_ref - s_mmt)))

    rows = [
        ("table1/acc_reference", acc_ref),
        ("table1/acc_mmt4d", acc_mmt),
        ("table1/argmax_agreement", agree),
        ("table1/max_abs_dloglik", max_dll),
    ]

    # The paper's Llama.cpp Q4/Q8 columns: same suite through the quantized
    # serving paths.  Quantization is lossy — the deliverable is decision
    # agreement with the full-precision scorer, not bitwise logits.
    for label, quant in (("w8a8", "int8"), ("w4a8", "int4")):
        enc_q = EncodingConfig(enabled=True, backend="xla", weight_quant=quant)
        params_q = T.model_init(jax.random.PRNGKey(seed), cfg, enc_q)
        s_q = _score_options(params_q, cfg, enc_q, prompts, options)
        rows.append(
            (f"table1/acc_{label}", float(np.mean(s_q.argmax(1) == answers)))
        )
        rows.append((
            f"table1/argmax_agreement_{label}",
            float(np.mean(s_ref.argmax(1) == s_q.argmax(1))),
        ))
        rows.append(
            (f"table1/max_abs_dloglik_{label}", float(np.max(np.abs(s_q - s_ref))))
        )
    dt = time.time() - t0

    derived = "PARITY" if (acc_ref == acc_mmt and agree == 1.0) else "MISMATCH"
    return rows, derived, dt


def main():
    rows, derived, dt = run()
    for name, val in rows:
        print(f"{name},{val:.6f},{derived}")
    print(f"table1/wall_s,{dt:.2f},{derived}")


if __name__ == "__main__":
    main()
