"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)      [s]
  memory term     = HLO_bytes / (chips x HBM_bw)           [s]
  collective term = collective_bytes / (chips x link_bw)   [s]

HLO terms are *per-device* from benchmarks/hlo_analysis.py (loop-aware), so
"/(chips x ...)" is already applied; the table reports per-step seconds, the
dominant term, MODEL_FLOPS = 6ND (dense) / 6*N_active*D (MoE) over the global
batch, and MODEL_FLOPS / (chips x HLO_FLOPs) — the useful-compute fraction.
"""

from __future__ import annotations

import glob
import json
import os

from repro.core import targets as targets_lib

T = targets_lib.TPU_V5E


def roofline_terms(rec: dict) -> dict:
    chips = rec["chips"]
    fl = rec["flops_per_device"]
    by = rec["bytes_per_device"]
    co = rec["collective_bytes_per_device"]
    t_compute = fl / T.peak_flops_bf16
    t_memory = by / T.hbm_bytes_per_s
    t_coll = co / T.ici_bytes_per_s
    dom = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]

    # Useful model FLOPs for this step (global).
    if rec["kind"] == "train":
        tokens = 4096 * 256
        mult = 6.0
    elif rec["shape"] == "prefill_32k":
        tokens = 32768 * 32
        mult = 2.0
    elif rec["shape"] == "decode_32k":
        tokens = 128  # one token per sequence
        mult = 2.0
    else:  # long_500k decode
        tokens = 1
        mult = 2.0
    model_flops = mult * rec["active_params"] * tokens
    useful = model_flops / (chips * fl) if fl else 0.0

    bound = max(t_compute, t_memory, t_coll)
    step_time = bound  # roofline lower bound on step time
    mfu = model_flops / (chips * T.peak_flops_bf16 * step_time) if step_time else 0.0
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": model_flops,
        "useful_fraction": useful,
        "roofline_mfu": mfu,
    }


def load_results(result_dir: str = "results/dryrun") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "skipped" in rec:
            out.append(rec)
            continue
        rec.update(roofline_terms(rec))
        out.append(rec)
    return out


def markdown_table(records: list[dict], mesh: str = "16x16") -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful frac | roofline MFU |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for r in records:
        if "skipped" in r:
            if mesh == "16x16":
                rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP: {r['skipped'][:40]} | — | — | — |")
            continue
        if r["mesh"] != mesh:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | {r['dominant']} | "
            f"{r['model_flops']:.2e} | {r['useful_fraction']:.3f} | {r['roofline_mfu']:.3f} |"
        )
    return hdr + "\n".join(rows) + "\n"


def main():
    recs = load_results()
    ok = [r for r in recs if "skipped" not in r]
    print(f"# {len(ok)} compiled cells, {len(recs) - len(ok)} documented skips")
    for mesh in ("16x16", "2x16x16"):
        if any(r.get("mesh") == mesh for r in recs):
            print(f"\n## mesh {mesh} (baseline)\n")
            print(markdown_table(recs, mesh))
    for r in ok:
        print(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s'])*1e6:.1f},"
            f"{r['dominant']}"
        )
    if os.path.isdir("results/dryrun_prod"):
        prod = [r for r in load_results("results/dryrun_prod") if "skipped" not in r]
        base = {(r["arch"], r["shape"], r["mesh"]): r for r in ok}
        print("\n# production profile (EXPERIMENTS.md §Perf levers)")
        for r in prod:
            b = base.get((r["arch"], r["shape"], r["mesh"]))
            pb = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
            bb = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"]) if b else 0
            speed = f"{bb / pb:.2f}x" if b and pb else ""
            print(
                f"roofline_prod/{r['arch']}/{r['shape']}/{r['mesh']},"
                f"{pb*1e6:.1f},{r['dominant']};speedup={speed}"
            )


if __name__ == "__main__":
    main()
