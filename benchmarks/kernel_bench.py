"""Per-kernel microbenchmark: correctness (interpret) + wall time (XLA path)
across the paper's shape regimes, plus the VMEM/block report for each
configuration (the structural profile used in §Perf).

`--tune` is the registry's autotune pass: for every dispatch key
(quant, phase, M-bucket, target) it measures the candidate kernel-block
shapes on a representative shape and persists the winners to the checked-in
tuned table (src/repro/kernels/tuned_table.json) that
`repro.kernels.registry.select` consults at dispatch time.  On this CPU
container the timings run interpret-mode Pallas — relative ordering between
block shapes is directional; re-run --tune on real hardware to re-measure."""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding, targets
from repro.core.encoding import Phase
from repro.kernels import attn as attn_lib
from repro.kernels import ops, ref
from repro.kernels import registry as registry_lib


def _time(fn, *args, iters=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


SHAPES = [
    # (phase, M, N, K) — prefill GEMM and decode GEMV regimes
    (Phase.PREFILL, 512, 2048, 1024),
    (Phase.PREFILL, 2048, 2048, 2048),
    (Phase.DECODE, 1, 4096, 1024),
    (Phase.DECODE, 8, 8192, 2048),
]


def main():
    rows = []
    for phase, m, n, k in SHAPES:
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(m, k), jnp.float32)
        w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
        rhs4 = ops.pack_rhs(w_t)

        # correctness in interpret mode (the Pallas kernel body itself)
        want = ref.matmul_reference(x, w_t)
        got = ops.encoded_matmul(
            x, rhs4, n=n, phase=phase, backend="pallas",
            out_dtype=jnp.float32, interpret=True,
        )
        err = float(jnp.max(jnp.abs(got - want)))

        # wall time of the XLA-lowered packed path vs reference
        f_mmt = jax.jit(lambda a, r: ops.encoded_matmul(
            a, r, n=n, phase=phase, backend="xla", out_dtype=jnp.float32))
        f_ref = jax.jit(lambda a, w: ref.matmul_reference(a, w))
        t_mmt = _time(f_mmt, x, rhs4)
        t_ref = _time(f_ref, x, w_t)

        # structural: selected kernel blocks + VMEM footprint
        n1, k1 = rhs4.shape[0], rhs4.shape[1]
        m0 = 128 if phase is not Phase.DECODE else min(8, m)
        kb = encoding.select_kernel_blocks(
            encoding.TileSizes(m0, 128, 128), phase,
            m1=max(1, m // m0), n1=n1, k1=k1, lhs_itemsize=4, rhs_itemsize=4,
        )
        vmem = (
            kb.bm1 * kb.bk1 * m0 * 128 * 4
            + kb.bn1 * kb.bk1 * 128 * 128 * 4
            + kb.bm1 * kb.bn1 * m0 * 128 * 4
        )
        tag = f"{phase.value}_m{m}_n{n}_k{k}"
        rows.append((f"kernel/{tag}/interpret_err", err, "allclose"))
        rows.append((f"kernel/{tag}/xla_mmt4d_us", t_mmt * 1e6, f"blocks={kb.bm1}x{kb.bn1}x{kb.bk1}"))
        rows.append((f"kernel/{tag}/xla_reference_us", t_ref * 1e6, ""))
        rows.append((f"kernel/{tag}/vmem_bytes", vmem, f"fits={vmem <= targets.TPU_V5E.vmem_bytes // 2}"))

        if phase is Phase.DECODE:
            # Decode fast path: fused GEMV correctness + the HBM bytes the
            # in-kernel pack/unpack removes vs the unfused pallas path.
            got_f = ops.encoded_matmul(
                x, rhs4, n=n, phase=phase, backend="fused",
                out_dtype=jnp.float32, interpret=True,
            )
            err_f = float(jnp.max(jnp.abs(got_f - want)))
            hbm = encoding.decode_projection_hbm_bytes(
                m, n, k, act_itemsize=4, weight_itemsize=4
            )
            rows.append((f"kernel/{tag}/fused_gemv_interpret_err", err_f, "allclose"))
            rows.append((
                f"kernel/{tag}/fused_gemv_hbm_bytes_saved",
                hbm["saved"],
                f"of_{hbm['unfused']}_unfused",
            ))
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")
    return rows


# ---- registry autotune (kernel_bench --tune) --------------------------------

# Representative live-row count per M-bucket (registry.m_bucket boundaries).
# "m32" is the spec-decode verify regime: slots x (draft_k + 1) rows.
_BUCKET_REPS = {"m1": 1, "m8": 8, "m32": 20, "m64": 48, "big": 192}

# Candidate kernel blocks (BM1, BN1, BK1) per phase kind.  Decode candidates
# sweep the GEMV streaming width BN1; prefill candidates sweep the VMEM-
# resident block.  All candidates divide the tune shape's tile counts.
_DECODE_CANDIDATES = [(1, 1, 1), (1, 2, 1), (1, 4, 1), (1, 8, 1)]
_PREFILL_CANDIDATES = [(1, 2, 1), (2, 2, 2), (1, 4, 2), (2, 8, 2)]


# Attention op-class candidates: (q_chunk, kv_chunk) streaming granularity
# (decode kernels use kv_chunk only; the stored blocks keep the 2-tuple).
# S reps land one representative context length inside each tuned bucket
# ("sbig" stays policy-routed — an 8k+ interpret sweep buys no information
# the s4k point does not already carry).
_ATTN_S_REPS = {"s256": 256, "s1k": 768, "s4k": 2048}
_ATTN_DECODE_CANDIDATES = [(1, 32), (1, 64), (1, 128)]
_ATTN_PREFILL_CANDIDATES = [(64, 64), (64, 128), (128, 128)]


def _tune_attn(entries: dict, *, iters: int) -> None:
    """Measure attention-kernel chunk candidates per (phase, S-bucket) key
    and add them to `entries` (kernels/attn.py dense decode + flash
    prefill; the paged kernel streams at page granularity and shares the
    decode entries' backend).

    Decode keys are measured across the kv-quant axis too (bf16 emits the
    legacy 4-segment key, kv8/kv4 the 5-segment form): the quantized kernels
    stream packed K/V plus scale slabs, so the winning chunk size can differ
    from bf16's.  Prefill stays bf16-only — flash prefill reads the
    full-precision temp cache; quantization happens at scatter.

    Like the matmul tuner, the recorded backend is the STATIC POLICY, never
    a cross-backend measurement: on this interpret-mode CPU container the
    jnp reference beats interpreted Pallas at every shape, so measuring
    backends here would permanently route serving off the kernels.  A
    target where the reference genuinely wins a bucket gets its entry
    pinned by a real-hardware measurement (the same convention as the
    hand-pinned tpu-v5e m64 "fused" matmul entries)."""
    target = targets.TPU_V5E
    rng = np.random.RandomState(0)
    b, kvh, g, d = 1, 2, 4, 32
    for phase in (Phase.DECODE, Phase.PREFILL):
        cands = (
            _ATTN_DECODE_CANDIDATES if phase is Phase.DECODE
            else _ATTN_PREFILL_CANDIDATES
        )
        kv_axis = registry_lib.KV_QUANTS if phase is Phase.DECODE else ("bf16",)
        for bucket, s_rep in _ATTN_S_REPS.items():
            backend = registry_lib.default_attn_backend(phase, bucket)
            k = jnp.asarray(rng.randn(b, s_rep, kvh, d), jnp.float32)
            v = jnp.asarray(rng.randn(b, s_rep, kvh, d), jnp.float32)
            for kvq in kv_axis:
                key = registry_lib.attn_dispatch_key(
                    phase, s_rep, target.name, kv=kvq
                )
                layout = encoding.kv_layout(kvq)
                if layout.quantized:
                    kq, ks = layout.quantize(k)
                    vq, vs = layout.quantize(v)
                best = None
                for qc, kc in cands:
                    if phase is Phase.DECODE:
                        q = jnp.asarray(
                            rng.randn(b, 1, kvh * g, d), jnp.float32)
                        pos = jnp.asarray([s_rep - 1], jnp.int32)
                        if layout.quantized:
                            fn = lambda: attn_lib.dense_decode_attention(
                                q, kq, vq, pos, k_scale=ks, v_scale=vs,
                                kv_quant=kvq, kv_chunk=kc, interpret=True,
                            )
                        else:
                            fn = lambda: attn_lib.dense_decode_attention(
                                q, k, v, pos, kv_chunk=kc, interpret=True
                            )
                    else:
                        sq = min(s_rep, 256)  # prefill band; KV carries S
                        q = jnp.asarray(
                            rng.randn(b, sq, kvh * g, d), jnp.float32)
                        off = s_rep - sq
                        fn = lambda: attn_lib.flash_prefill_attention(
                            q, k, v, causal=True, q_offset=off,
                            q_chunk=qc, kv_chunk=kc, interpret=True,
                        )
                    t = _time(fn, iters=iters, warmup=1)
                    print(f"tune/{key}/blocks={qc}x{kc},{t * 1e6:.1f},us")
                    if best is None or t < best[0]:
                        best = (t, (qc, kc))
                entries[key] = {
                    "backend": backend,
                    "blocks": list(best[1]),
                    "us": round(best[0] * 1e6, 1),
                    "shape_bsd": [b, s_rep, kvh * g * d],
                }


def tune(
    out_path: str | None = None,
    *,
    iters: int = 2,
    op_classes: tuple[str, ...] = ("matmul", "attn"),
) -> str:
    """Measure candidate tile/block shapes per dispatch key and persist the
    winning table.  Returns the path written.

    `op_classes` picks which classes to re-measure; keys of classes NOT
    re-measured this run are carried over from the existing table unchanged
    (a partial retune must not drop the other class's entries)."""
    target = targets.TPU_V5E
    n, k = 1024, 256  # N1=8, K1=2: every candidate divides the tile counts
    rng = np.random.RandomState(0)
    w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
    packed = {
        "none": (ops.pack_rhs(w_t),),
        "w8a8": ops.pack_rhs_q8(w_t),
        "w4a8": ops.pack_rhs_q4(w_t),
    }

    def run(quant, phase, m, backend, blocks):
        # Measurement pins the POLICY backend explicitly — "auto" would read
        # the very table being regenerated.
        x = jnp.asarray(rng.randn(m, k), jnp.float32)
        if quant == "none":
            fn = lambda: ops.encoded_matmul(
                x, packed[quant][0], n=n, phase=phase, backend=backend,
                blocks=blocks, out_dtype=jnp.float32, interpret=True,
            )
        elif quant == "w8a8":
            fn = lambda: ops.encoded_matmul_q8(
                x, *packed[quant], n=n, phase=phase, backend=backend,
                blocks=blocks, out_dtype=jnp.float32, interpret=True,
            )
        else:
            fn = lambda: ops.encoded_matmul_q4(
                x, *packed[quant], n=n, phase=phase, backend=backend,
                blocks=blocks, out_dtype=jnp.float32, interpret=True,
            )
        return _time(fn, iters=iters, warmup=1)

    # Carry over entries this run will not re-measure.  The matmul class is
    # dropped wholesale when re-measured (every matmul key is regenerated
    # below), but the attn class merges at KEY level: _tune_attn overwrites
    # exactly the keys it measures, and any other attn entry — a 5-part
    # kv-quant key pinned on real hardware, another target's key — is
    # preserved.  Dropping those on every retune would silently erase the
    # kv axis of the table.
    entries = {
        k: dict(v)
        for k, v in registry_lib.load_table(out_path)["entries"].items()
        if k.startswith("attn|") or "matmul" not in op_classes
    }
    if "attn" in op_classes:
        _tune_attn(entries, iters=iters)
    if "matmul" not in op_classes:
        path = registry_lib.save_table({"entries": entries}, out_path)
        print(f"tune/table_written,{len(entries)},{path}")
        return path
    for quant in registry_lib.QUANTS:
        for phase in (Phase.DECODE, Phase.PREFILL):
            cands = (
                _DECODE_CANDIDATES if phase is Phase.DECODE else _PREFILL_CANDIDATES
            )
            buckets = ("m1", "m8", "m32", "m64") if phase is Phase.DECODE else (
                "m64", "big"
            )
            for bucket in buckets:
                m = _BUCKET_REPS[bucket]
                key = registry_lib.dispatch_key(quant, phase, m, target.name)
                # Backend comes from the static policy, NOT select(): select
                # reads the existing tuned table, and copying its backend
                # would let a stale entry survive every retune.
                backend = registry_lib.default_backend(quant, phase, bucket)
                best = None
                for cand in cands:
                    t = run(quant, phase, m, backend, cand)
                    print(
                        f"tune/{key}/blocks={cand[0]}x{cand[1]}x{cand[2]},"
                        f"{t * 1e6:.1f},us"
                    )
                    if best is None or t < best[0]:
                        best = (t, cand)
                entries[key] = {
                    "backend": backend,
                    "blocks": list(best[1]),
                    "us": round(best[0] * 1e6, 1),
                    "shape_mnk": [m, n, k],
                }
    path = registry_lib.save_table({"entries": entries}, out_path)
    print(f"tune/table_written,{len(entries)},{path}")
    return path


if __name__ == "__main__":
    if "--tune" in sys.argv[1:] or "--tune-attn" in sys.argv[1:]:
        out = None
        if "--out" in sys.argv[1:]:
            out = sys.argv[sys.argv.index("--out") + 1]
        classes = ("attn",) if "--tune-attn" in sys.argv[1:] else ("matmul", "attn")
        tune(out, op_classes=classes)
    else:
        main()
