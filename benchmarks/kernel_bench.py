"""Per-kernel microbenchmark: correctness (interpret) + wall time (XLA path)
across the paper's shape regimes, plus the VMEM/block report for each
configuration (the structural profile used in §Perf).

`--tune` is the registry's autotune pass: for every dispatch key
(quant, phase, M-bucket, target) it measures the candidate kernel-block
shapes on a representative shape and persists the winners to the checked-in
tuned table (src/repro/kernels/tuned_table.json) that
`repro.kernels.registry.select` consults at dispatch time.  On this CPU
container the timings run interpret-mode Pallas — relative ordering between
block shapes is directional; re-run --tune on real hardware to re-measure."""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding, targets
from repro.core.encoding import Phase
from repro.kernels import ops, ref
from repro.kernels import registry as registry_lib


def _time(fn, *args, iters=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


SHAPES = [
    # (phase, M, N, K) — prefill GEMM and decode GEMV regimes
    (Phase.PREFILL, 512, 2048, 1024),
    (Phase.PREFILL, 2048, 2048, 2048),
    (Phase.DECODE, 1, 4096, 1024),
    (Phase.DECODE, 8, 8192, 2048),
]


def main():
    rows = []
    for phase, m, n, k in SHAPES:
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(m, k), jnp.float32)
        w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
        rhs4 = ops.pack_rhs(w_t)

        # correctness in interpret mode (the Pallas kernel body itself)
        want = ref.matmul_reference(x, w_t)
        got = ops.encoded_matmul(
            x, rhs4, n=n, phase=phase, backend="pallas",
            out_dtype=jnp.float32, interpret=True,
        )
        err = float(jnp.max(jnp.abs(got - want)))

        # wall time of the XLA-lowered packed path vs reference
        f_mmt = jax.jit(lambda a, r: ops.encoded_matmul(
            a, r, n=n, phase=phase, backend="xla", out_dtype=jnp.float32))
        f_ref = jax.jit(lambda a, w: ref.matmul_reference(a, w))
        t_mmt = _time(f_mmt, x, rhs4)
        t_ref = _time(f_ref, x, w_t)

        # structural: selected kernel blocks + VMEM footprint
        n1, k1 = rhs4.shape[0], rhs4.shape[1]
        m0 = 128 if phase is not Phase.DECODE else min(8, m)
        kb = encoding.select_kernel_blocks(
            encoding.TileSizes(m0, 128, 128), phase,
            m1=max(1, m // m0), n1=n1, k1=k1, lhs_itemsize=4, rhs_itemsize=4,
        )
        vmem = (
            kb.bm1 * kb.bk1 * m0 * 128 * 4
            + kb.bn1 * kb.bk1 * 128 * 128 * 4
            + kb.bm1 * kb.bn1 * m0 * 128 * 4
        )
        tag = f"{phase.value}_m{m}_n{n}_k{k}"
        rows.append((f"kernel/{tag}/interpret_err", err, "allclose"))
        rows.append((f"kernel/{tag}/xla_mmt4d_us", t_mmt * 1e6, f"blocks={kb.bm1}x{kb.bn1}x{kb.bk1}"))
        rows.append((f"kernel/{tag}/xla_reference_us", t_ref * 1e6, ""))
        rows.append((f"kernel/{tag}/vmem_bytes", vmem, f"fits={vmem <= targets.TPU_V5E.vmem_bytes // 2}"))

        if phase is Phase.DECODE:
            # Decode fast path: fused GEMV correctness + the HBM bytes the
            # in-kernel pack/unpack removes vs the unfused pallas path.
            got_f = ops.encoded_matmul(
                x, rhs4, n=n, phase=phase, backend="fused",
                out_dtype=jnp.float32, interpret=True,
            )
            err_f = float(jnp.max(jnp.abs(got_f - want)))
            hbm = encoding.decode_projection_hbm_bytes(
                m, n, k, act_itemsize=4, weight_itemsize=4
            )
            rows.append((f"kernel/{tag}/fused_gemv_interpret_err", err_f, "allclose"))
            rows.append((
                f"kernel/{tag}/fused_gemv_hbm_bytes_saved",
                hbm["saved"],
                f"of_{hbm['unfused']}_unfused",
            ))
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")
    return rows


# ---- registry autotune (kernel_bench --tune) --------------------------------

# Representative live-row count per M-bucket (registry.m_bucket boundaries).
# "m32" is the spec-decode verify regime: slots x (draft_k + 1) rows.
_BUCKET_REPS = {"m1": 1, "m8": 8, "m32": 20, "m64": 48, "big": 192}

# Candidate kernel blocks (BM1, BN1, BK1) per phase kind.  Decode candidates
# sweep the GEMV streaming width BN1; prefill candidates sweep the VMEM-
# resident block.  All candidates divide the tune shape's tile counts.
_DECODE_CANDIDATES = [(1, 1, 1), (1, 2, 1), (1, 4, 1), (1, 8, 1)]
_PREFILL_CANDIDATES = [(1, 2, 1), (2, 2, 2), (1, 4, 2), (2, 8, 2)]


def tune(out_path: str | None = None, *, iters: int = 2) -> str:
    """Measure candidate tile/block shapes per dispatch key and persist the
    winning table.  Returns the path written."""
    target = targets.TPU_V5E
    n, k = 1024, 256  # N1=8, K1=2: every candidate divides the tile counts
    rng = np.random.RandomState(0)
    w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
    packed = {
        "none": (ops.pack_rhs(w_t),),
        "w8a8": ops.pack_rhs_q8(w_t),
        "w4a8": ops.pack_rhs_q4(w_t),
    }

    def run(quant, phase, m, backend, blocks):
        # Measurement pins the POLICY backend explicitly — "auto" would read
        # the very table being regenerated.
        x = jnp.asarray(rng.randn(m, k), jnp.float32)
        if quant == "none":
            fn = lambda: ops.encoded_matmul(
                x, packed[quant][0], n=n, phase=phase, backend=backend,
                blocks=blocks, out_dtype=jnp.float32, interpret=True,
            )
        elif quant == "w8a8":
            fn = lambda: ops.encoded_matmul_q8(
                x, *packed[quant], n=n, phase=phase, backend=backend,
                blocks=blocks, out_dtype=jnp.float32, interpret=True,
            )
        else:
            fn = lambda: ops.encoded_matmul_q4(
                x, *packed[quant], n=n, phase=phase, backend=backend,
                blocks=blocks, out_dtype=jnp.float32, interpret=True,
            )
        return _time(fn, iters=iters, warmup=1)

    entries = {}
    for quant in registry_lib.QUANTS:
        for phase in (Phase.DECODE, Phase.PREFILL):
            cands = (
                _DECODE_CANDIDATES if phase is Phase.DECODE else _PREFILL_CANDIDATES
            )
            buckets = ("m1", "m8", "m32", "m64") if phase is Phase.DECODE else (
                "m64", "big"
            )
            for bucket in buckets:
                m = _BUCKET_REPS[bucket]
                key = registry_lib.dispatch_key(quant, phase, m, target.name)
                # Backend comes from the static policy, NOT select(): select
                # reads the existing tuned table, and copying its backend
                # would let a stale entry survive every retune.
                backend = registry_lib.default_backend(quant, phase, bucket)
                best = None
                for cand in cands:
                    t = run(quant, phase, m, backend, cand)
                    print(
                        f"tune/{key}/blocks={cand[0]}x{cand[1]}x{cand[2]},"
                        f"{t * 1e6:.1f},us"
                    )
                    if best is None or t < best[0]:
                        best = (t, cand)
                entries[key] = {
                    "backend": backend,
                    "blocks": list(best[1]),
                    "us": round(best[0] * 1e6, 1),
                    "shape_mnk": [m, n, k],
                }
    path = registry_lib.save_table({"entries": entries}, out_path)
    print(f"tune/table_written,{len(entries)},{path}")
    return path


if __name__ == "__main__":
    if "--tune" in sys.argv[1:]:
        out = None
        if "--out" in sys.argv[1:]:
            out = sys.argv[sys.argv.index("--out") + 1]
        tune(out)
    else:
        main()
