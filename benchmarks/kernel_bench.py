"""Per-kernel microbenchmark: correctness (interpret) + wall time (XLA path)
across the paper's shape regimes, plus the VMEM/block report for each
configuration (the structural profile used in §Perf)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding, targets
from repro.core.encoding import Phase
from repro.kernels import ops, ref


def _time(fn, *args, iters=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


SHAPES = [
    # (phase, M, N, K) — prefill GEMM and decode GEMV regimes
    (Phase.PREFILL, 512, 2048, 1024),
    (Phase.PREFILL, 2048, 2048, 2048),
    (Phase.DECODE, 1, 4096, 1024),
    (Phase.DECODE, 8, 8192, 2048),
]


def main():
    rows = []
    for phase, m, n, k in SHAPES:
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(m, k), jnp.float32)
        w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
        rhs4 = ops.pack_rhs(w_t)

        # correctness in interpret mode (the Pallas kernel body itself)
        want = ref.matmul_reference(x, w_t)
        got = ops.encoded_matmul(
            x, rhs4, n=n, phase=phase, backend="pallas",
            out_dtype=jnp.float32, interpret=True,
        )
        err = float(jnp.max(jnp.abs(got - want)))

        # wall time of the XLA-lowered packed path vs reference
        f_mmt = jax.jit(lambda a, r: ops.encoded_matmul(
            a, r, n=n, phase=phase, backend="xla", out_dtype=jnp.float32))
        f_ref = jax.jit(lambda a, w: ref.matmul_reference(a, w))
        t_mmt = _time(f_mmt, x, rhs4)
        t_ref = _time(f_ref, x, w_t)

        # structural: selected kernel blocks + VMEM footprint
        tiles = encoding.select_tile_sizes(phase, lhs_dtype=jnp.float32, m_hint=m)
        n1, k1 = rhs4.shape[0], rhs4.shape[1]
        m0 = 128 if phase is not Phase.DECODE else min(8, m)
        kb = encoding.select_kernel_blocks(
            encoding.TileSizes(m0, 128, 128), phase,
            m1=max(1, m // m0), n1=n1, k1=k1, lhs_itemsize=4, rhs_itemsize=4,
        )
        vmem = (
            kb.bm1 * kb.bk1 * m0 * 128 * 4
            + kb.bn1 * kb.bk1 * 128 * 128 * 4
            + kb.bm1 * kb.bn1 * m0 * 128 * 4
        )
        tag = f"{phase.value}_m{m}_n{n}_k{k}"
        rows.append((f"kernel/{tag}/interpret_err", err, "allclose"))
        rows.append((f"kernel/{tag}/xla_mmt4d_us", t_mmt * 1e6, f"blocks={kb.bm1}x{kb.bn1}x{kb.bk1}"))
        rows.append((f"kernel/{tag}/xla_reference_us", t_ref * 1e6, ""))
        rows.append((f"kernel/{tag}/vmem_bytes", vmem, f"fits={vmem <= targets.TPU_V5E.vmem_bytes // 2}"))

        if phase is Phase.DECODE:
            # Decode fast path: fused GEMV correctness + the HBM bytes the
            # in-kernel pack/unpack removes vs the unfused pallas path.
            got_f = ops.encoded_matmul(
                x, rhs4, n=n, phase=phase, backend="fused",
                out_dtype=jnp.float32, interpret=True,
            )
            err_f = float(jnp.max(jnp.abs(got_f - want)))
            hbm = encoding.decode_projection_hbm_bytes(
                m, n, k, act_itemsize=4, weight_itemsize=4
            )
            rows.append((f"kernel/{tag}/fused_gemv_interpret_err", err_f, "allclose"))
            rows.append((
                f"kernel/{tag}/fused_gemv_hbm_bytes_saved",
                hbm["saved"],
                f"of_{hbm['unfused']}_unfused",
            ))
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")
    return rows


if __name__ == "__main__":
    main()
