"""HLO text analysis: per-device FLOPs / HBM bytes / collective traffic.

XLA's compiled.cost_analysis() does NOT multiply while-loop trip counts (a
lax.scan body is counted once), so none of its totals are usable for a model
that scans over layers.  This module re-derives all three roofline terms from
the post-SPMD-partitioning HLO text:

  * computations are split with a column-0 state machine,
  * while-loop trip counts come from the largest s32 constant in the loop
    condition; multipliers propagate down the call graph (ENTRY=1, a
    collective inside the 56-group layer scan counts 56x),
  * compute term: dot-op FLOPs = 2 * prod(result dims) * prod(lhs contracting
    dims) (MXU work; elementwise VPU work is ignored by design),
  * memory term: per-op HBM traffic = result + operand bytes for ops at
    control-flow level (fusion internals live in registers/VMEM and are
    excluded; the fusion node's own operands/results are the HBM boundary),
  * collective term: result-shape bytes converted to link traffic:
        all-gather          ~ result          all-reduce     ~ 2 x result
        reduce-scatter      ~ result x group  all-to-all     ~ result
        collective-permute  ~ result
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_WORD_PAREN = re.compile(r"([\w\-]+)\($")


def _split_op(rhs: str) -> tuple[str, str, str] | None:
    """Split 'TYPE opcode(operands...), attrs' where TYPE may be a tuple type
    containing parens and /*index=N*/ comments.  The opcode is the first
    word+'(' at paren depth 0 after the type."""
    depth = 0
    for i, ch in enumerate(rhs):
        if ch == "(":
            if depth == 0:
                m = _WORD_PAREN.search(rhs[: i + 1])
                if m and (m.start() == 0 or rhs[m.start() - 1] == " "):
                    return rhs[: m.start()].strip(), m.group(1), rhs[i + 1 :]
            depth += 1
        elif ch == ")":
            depth -= 1
    return None
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "while", "call",
    "conditional", "bitcast", "after-all", "custom-call", "partition-id",
    "replica-id", "iota",
}


def _parse_shapes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            if line and not line[0].isspace() and line.rstrip().endswith("{"):
                name = line.split()[0]
                if name == "ENTRY" and len(line.split()) > 1:
                    name = line.split()[1]
                name = name.split("(")[0].lstrip("%").rstrip(",")
                cur = name
                comps[cur] = []
            continue
        if line and not line[0].isspace() and line.strip().startswith("}"):
            cur = None
            continue
        comps[cur].append(line)
    return comps


class _Comp:
    def __init__(self, name: str, lines: list[str]):
        self.name = name
        self.lines = lines
        self.defs: dict[str, str] = {}  # op name -> result-type text
        self.opcodes: dict[str, str] = {}  # op name -> opcode
        self.op_rest: dict[str, str] = {}  # op name -> operands/attrs text
        self.ops: list[tuple[str, str, str, str]] = []  # (name, type, opcode, rest)
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            opname, rhs = dm.group(1), dm.group(2)
            parts = _split_op(rhs)
            if parts is None:
                continue
            rtype, opcode, rest = parts
            self.defs[opname] = rtype
            self.opcodes[opname] = opcode
            self.op_rest[opname] = rest
            self.ops.append((opname, rtype, opcode, rest))

    def shape_of(self, operand: str) -> list[tuple[str, list[int]]]:
        t = self.defs.get(operand.lstrip("%"))
        return _parse_shapes(t) if t else []

    def op_of(self, operand: str) -> str | None:
        return self.opcodes.get(operand.lstrip("%"))


def _trip_count(comp: "_Comp | None") -> int:
    if comp is None:
        return 1
    best = 1
    for ln in comp.lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(comp: _Comp, rtype: str, rest: str) -> float:
    shapes = _parse_shapes(rtype)
    result_elems = 1
    for _, dims in shapes:
        for d in dims:
            result_elems *= d
    # contraction size from lhs operand shape + lhs_contracting_dims
    mo = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    # Operands may carry inline type annotations depending on the HLO emitter:
    # "dot(%a, %b)" or "dot(f32[256,256]{1,0} %a, ...)" — match the %names.
    operand_text = rest.split(")")[0]
    operands = re.findall(r"%[\w.\-]+", operand_text)
    csize = 1
    if mo and operands:
        lhs_shapes = comp.shape_of(operands[0])
        if not lhs_shapes:
            # Operand defined outside this computation (or a parameter whose
            # def didn't parse): fall back to the inline type annotation that
            # immediately precedes the operand name (the last shape parsed
            # from the preceding text — shape dims contain commas, so no
            # comma splitting here).
            pre = operand_text.split(operands[0])[0]
            lhs_shapes = _parse_shapes(pre)[-1:]
        if lhs_shapes:
            _, dims = lhs_shapes[0]
            for idx in (int(i) for i in mo.group(1).split(",") if i):
                if idx < len(dims):
                    csize *= dims[idx]
    return 2.0 * result_elems * csize


def analyze(hlo: str, *, detail: bool = False) -> dict:
    raw = _split_computations(hlo)
    comps = {name: _Comp(name, lines) for name, lines in raw.items()}

    loops: list[tuple[str, str, str]] = []
    calls: list[tuple[str, str]] = []          # control-flow calls (bytes count)
    fusion_calls: list[tuple[str, str]] = []   # fusion/to_apply (bytes skip)
    for comp in comps.values():
        for opname, rtype, opcode, rest in comp.ops:
            if opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", rest)
                mc = re.search(r"condition=%?([\w.\-]+)", rest)
                if mb and mc:
                    loops.append((comp.name, mb.group(1), mc.group(1)))
            elif opcode in ("call", "conditional", "async-start"):
                for m in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)", rest):
                    calls.append((comp.name, m.group(1)))
                for m in re.finditer(r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w.\-]+)", rest):
                    calls.append((comp.name, m.group(1)))
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", rest):
                    fusion_calls.append((comp.name, m.group(1)))

    called = (
        {b for _, b, _ in loops} | {c for _, _, c in loops}
        | {t for _, t in calls} | {t for _, t in fusion_calls}
    )
    mult: dict[str, float] = defaultdict(float)
    fusion_ctx: dict[str, bool] = defaultdict(bool)  # True if reached via fusion
    for name in comps:
        if name not in called:
            mult[name] = 1.0

    for _ in range(128):
        changed = False
        for parent, body, cond in loops:
            if mult[parent] <= 0:
                continue
            tc = _trip_count(comps.get(cond))
            for tgt, k in ((body, tc), (cond, tc)):
                want = mult[parent] * k
                if mult[tgt] < want:
                    mult[tgt] = want
                    changed = True
                if fusion_ctx[parent] and not fusion_ctx[tgt]:
                    fusion_ctx[tgt] = True
                    changed = True
        for parent, tgt in calls:
            if mult[parent] > 0 and mult[tgt] < mult[parent]:
                mult[tgt] = mult[parent]
                changed = True
            if mult[parent] > 0 and fusion_ctx[parent] and not fusion_ctx[tgt]:
                fusion_ctx[tgt] = True
                changed = True
        for parent, tgt in fusion_calls:
            if mult[parent] > 0:
                if mult[tgt] < mult[parent]:
                    mult[tgt] = mult[parent]
                    changed = True
                if not fusion_ctx[tgt]:
                    fusion_ctx[tgt] = True
                    changed = True
        if not changed:
            break

    while_bodies = {b for _, b, _ in loops} | {
        t for p, t in calls if any(p == b for _, b, _ in loops)
    }
    # computations transitively inside while bodies (fusion bodies included)
    inside_loop: set[str] = set(while_bodies)
    for _ in range(32):
        grew = False
        for p, t in calls + fusion_calls:
            if p in inside_loop and t not in inside_loop:
                inside_loop.add(t)
                grew = True
        for p, b, c in loops:
            if p in inside_loop:
                for t in (b, c):
                    if t not in inside_loop:
                        inside_loop.add(t)
                        grew = True
        if not grew:
            break

    flops = 0.0
    hbm_bytes = 0.0        # unfused upper bound: every op result+operands
    fused_bytes = 0.0      # fused model: DS/DUS + dot streams + carried state
    per_coll: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, int] = defaultdict(int)
    detail_bytes: dict[str, float] = defaultdict(float)
    detail_flops: dict[str, float] = defaultdict(float)
    detail_coll: dict[str, float] = defaultdict(float)

    def _meta_tag(rest: str) -> str:
        m = re.search(r'op_name="([^"]*)"', rest)
        if not m:
            return "<none>"
        # Keep the trailing, most specific path elements.
        return "/".join(m.group(1).split("/")[-3:])[:90]

    for comp in comps.values():
        m = mult[comp.name] if mult[comp.name] > 0 else 0.0
        if m == 0.0:
            continue
        for opname, rtype, opcode, rest in comp.ops:
            if opcode == "dot":
                f = m * _dot_flops(comp, rtype, rest)
                flops += f
                if detail:
                    detail_flops[f"dot:{_meta_tag(rest)}"] += f
            if opcode in _COLLECTIVES or any(
                opcode == c + sfx for c in _COLLECTIVES for sfx in ("-start",)
            ):
                base = opcode.replace("-start", "")
                if base in _COLLECTIVES:
                    nbytes = _shape_bytes(rtype)
                    group = 1
                    gm = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
                    if gm:
                        group = len(gm.group(1).split(","))
                    else:
                        gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
                        if gm2:
                            group = int(gm2.group(2))
                    if base == "all-reduce":
                        traffic = 2 * nbytes
                    elif base == "reduce-scatter":
                        traffic = nbytes * group
                    else:
                        traffic = nbytes
                    per_coll[base] += m * traffic
                    coll_counts[base] += 1
                    if detail:
                        detail_coll[f"{base}:{_meta_tag(rest)}"] += m * traffic
            # HBM bytes, unfused upper bound: control-flow-level ops only.
            if not fusion_ctx[comp.name] and opcode not in _SKIP_BYTES_OPS:
                nbytes = _shape_bytes(rtype)
                for operand in re.findall(r"%[\w.\-]+", rest.split("metadata")[0]):
                    nbytes += _operand_bytes(comp, operand)
                hbm_bytes += m * nbytes

            # HBM bytes, fused model (TPU semantics: loop-body intermediates
            # live in VMEM; HBM sees slice reads, update writes, weight
            # streams into the MXU, and the loop-carried state):
            if comp.name in inside_loop:
                add = 0.0
                if opcode == "dynamic-slice":
                    add = m * _shape_bytes(rtype)
                elif opcode == "dynamic-update-slice":
                    ops_ = _operand_names(rest)
                    if len(ops_) >= 2:
                        add = m * _operand_bytes(comp, ops_[1])
                elif opcode == "dot":
                    for operand in _operand_names(rest)[:2]:
                        src = comp.op_of(operand)
                        if src in ("dynamic-slice",):
                            continue  # stream already counted at the slice
                        add += m * _operand_bytes(comp, operand)
                fused_bytes += add
                if detail and add:
                    detail_bytes[f"{opcode}:{_meta_tag(rest)}"] += add
            elif not fusion_ctx[comp.name] and opcode not in _SKIP_BYTES_OPS:
                nbytes = _shape_bytes(rtype)
                for operand in re.findall(r"%[\w.\-]+", rest.split("metadata")[0]):
                    nbytes += _operand_bytes(comp, operand)
                fused_bytes += m * nbytes
                if detail and nbytes:
                    detail_bytes[f"{opcode}:{_meta_tag(rest)}"] += m * nbytes

    # Loop-carried state traffic: per iteration, each ROOT-tuple element of a
    # while body that is not a pass-through get-tuple-element costs a
    # read+write of its own size.
    for _, body, _ in loops:
        comp = comps.get(body)
        if comp is None or mult[body] <= 0:
            continue
        root = None
        for ln in comp.lines:
            if "ROOT" in ln:
                root = ln
        if not root:
            continue
        parts = _split_op(root.split("=", 1)[1].strip() if "=" in root else "")
        if not parts or parts[1] != "tuple":
            continue
        for operand in _operand_names(parts[2]):
            d = comp.defs.get(operand.lstrip("%"), "")
            src = comp.op_of(operand)
            if src in ("get-tuple-element", "parameter"):
                continue  # pass-through
            if src == "fusion":
                # In-place accumulation (lax.map output / scan ys buffers):
                # a DUS-fusion's traffic is its update slice, counted above.
                called = re.search(
                    r"calls=%?([\w.\-]+)", comp.op_rest.get(operand.lstrip("%"), "")
                )
                if called and any(
                    oc == "dynamic-update-slice"
                    for _, _, oc, _ in comps.get(called.group(1), _EMPTY).ops
                ):
                    continue
            fused_bytes += 2 * mult[body] * _shape_bytes(d)
            if detail:
                detail_bytes[f"carry:{body[:40]}:{operand[:30]}"] += (
                    2 * mult[body] * _shape_bytes(d)
                )

    out = {
        "flops": flops,
        "hbm_bytes": fused_bytes,
        "hbm_bytes_unfused": hbm_bytes,
        "collective_bytes": float(sum(per_coll.values())),
        "collective_per_op": dict(per_coll),
        "collective_counts": dict(coll_counts),
    }
    if detail:
        out["detail_bytes"] = dict(
            sorted(detail_bytes.items(), key=lambda kv: -kv[1])[:25]
        )
        out["detail_flops"] = dict(
            sorted(detail_flops.items(), key=lambda kv: -kv[1])[:25]
        )
        out["detail_coll"] = dict(
            sorted(detail_coll.items(), key=lambda kv: -kv[1])[:25]
        )
    return out


class _EmptyComp:
    ops: list = []


_EMPTY = _EmptyComp()


def _operand_names(rest: str) -> list[str]:
    return re.findall(r"%[\w.\-]+", rest.split("), ")[0])


def _operand_bytes(comp: "_Comp", operand: str) -> int:
    total = 0
    for dt, dims in comp.shape_of(operand):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str) -> dict:
    a = analyze(hlo)
    return {
        "total_bytes": a["collective_bytes"],
        "per_op": a["collective_per_op"],
        "counts": a["collective_counts"],
    }
