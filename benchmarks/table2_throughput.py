"""Table 2 analog: prefill/decode tokens-per-second across matmul paths.

Paper columns {llama.cpp, upstream IREE, 10x-IREE} map to:
  naive      weights stored (K, N), transposed+packed EVERY call — the
             unprepared-layout baseline (llama.cpp-class data movement)
  reference  plain jnp contraction, weights (N, K) — upstream-XLA analogue
  mmt4d      weights pre-packed once, einsum on the packed 4-D layout — the
             paper's path ("10x-IREE")

CPU wall-clock is directionally meaningful only (this container is not the
TPU target); the TPU projection lives in EXPERIMENTS.md §Roofline.  The
paper's thread sweep (1 vs 8) has no analogue on this 1-core container and is
replaced by the mesh sweep in the dry-run.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.kernels import ops, ref
from repro.models import transformer as T
from repro.serving import engine as engine_lib


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def model_throughput(arch: str = "llama3.2-1b", prefill_len: int = 64, decode_steps: int = 8):
    """End-to-end model tokens/s for reference vs mmt4d paths."""
    cfg = registry.get_reduced(arch)
    rows = []
    for label, enc in (
        ("reference", EncodingConfig(enabled=False, backend="reference")),
        ("mmt4d", EncodingConfig(enabled=True, backend="xla")),
    ):
        params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
        toks = jnp.ones((1, prefill_len), jnp.int32)
        caches = T.cache_init(cfg, 1, max_seq=prefill_len + decode_steps + 1)
        prefill = jax.jit(engine_lib.make_prefill_step(cfg, enc))
        decode = jax.jit(engine_lib.make_decode_step(cfg, enc))

        t_pre = _time(lambda: prefill(params, toks, caches)[0])
        rows.append((f"table2/prefill_tok_s/{label}", prefill_len / t_pre))

        _, caches2 = prefill(params, toks, caches)
        tok = jnp.ones((1, 1), jnp.int32)

        def dec_loop():
            c = caches2
            t = tok
            for i in range(decode_steps):
                t, _, c = decode(params, c, t, jnp.asarray(prefill_len + i - 1, jnp.int32))
            return t

        t_dec = _time(dec_loop)
        rows.append((f"table2/decode_tok_s/{label}", decode_steps / t_dec))
    return rows


def op_level_throughput(d_model: int = 1024, d_ff: int = 4096, batch: int = 1):
    """Per-matmul decode GEMV: the paper's core claim at op granularity.

    naive repacks the weight every call (what a runtime without device
    encodings does); mmt4d packs once at load."""
    rows = []
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(batch, d_model), jnp.float32)
    w_kn = jnp.asarray(rng.randn(d_model, d_ff), jnp.float32)   # (K, N) layout
    w_nk = jnp.asarray(w_kn.T)                                   # (N, K) layout
    rhs4 = ops.pack_rhs(w_nk)                                    # packed once

    @jax.jit
    def naive(x, w_kn):
        rhs = ref.pack(w_kn.T, (128, 128))  # per-call transpose + pack
        return ops.encoded_matmul(x, rhs, n=d_ff, phase=Phase.DECODE,
                                  backend="xla", out_dtype=jnp.float32)

    @jax.jit
    def reference(x, w_nk):
        return ref.matmul_reference(x, w_nk)

    @jax.jit
    def mmt4d(x, rhs4):
        return ops.encoded_matmul(x, rhs4, n=d_ff, phase=Phase.DECODE,
                                  backend="xla", out_dtype=jnp.float32)

    t_naive = _time(naive, x, w_kn)
    t_ref = _time(reference, x, w_nk)
    t_mmt = _time(mmt4d, x, rhs4)
    rows.append(("table2/op_decode_us/naive_repack", t_naive * 1e6))
    rows.append(("table2/op_decode_us/reference", t_ref * 1e6))
    rows.append(("table2/op_decode_us/mmt4d_prepacked", t_mmt * 1e6))
    rows.append(("table2/op_decode_speedup_vs_naive", t_naive / t_mmt))
    return rows


def main():
    for name, val in model_throughput():
        print(f"{name},{val:.4f},cpu-wall-clock")
    for name, val in op_level_throughput():
        print(f"{name},{val:.4f},cpu-wall-clock")


if __name__ == "__main__":
    main()
