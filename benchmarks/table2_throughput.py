"""Table 2 analog: prefill/decode tokens-per-second across matmul paths.

Paper columns {llama.cpp, upstream IREE, 10x-IREE} map to:
  naive      weights stored (K, N), transposed+packed EVERY call — the
             unprepared-layout baseline (llama.cpp-class data movement)
  reference  plain jnp contraction, weights (N, K) — upstream-XLA analogue
  mmt4d      weights pre-packed once, einsum on the packed 4-D layout — the
             paper's path ("10x-IREE")

CPU wall-clock is directionally meaningful only (this container is not the
TPU target); the TPU projection lives in EXPERIMENTS.md §Roofline.  The
paper's thread sweep (1 vs 8) has no analogue on this 1-core container and is
replaced by the mesh sweep in the dry-run.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import encoding
from repro.core.encoding import Phase, decode_projection_hbm_bytes
from repro.core.packed import EncodingConfig
from repro.kernels import ops, ref
from repro.kernels import registry as kernel_registry
from repro.models import transformer as T
from repro.serving import engine as engine_lib
from repro.serving import faults as faults_lib


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def model_throughput(arch: str = "llama3.2-1b", prefill_len: int = 64, decode_steps: int = 8):
    """End-to-end model tokens/s for reference vs mmt4d paths."""
    cfg = registry.get_reduced(arch)
    rows = []
    for label, enc in (
        ("reference", EncodingConfig(enabled=False, backend="reference")),
        ("mmt4d", EncodingConfig(enabled=True, backend="xla")),
    ):
        params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
        toks = jnp.ones((1, prefill_len), jnp.int32)
        caches = T.cache_init(cfg, 1, max_seq=prefill_len + decode_steps + 1)
        prefill = jax.jit(engine_lib.make_prefill_step(cfg, enc))
        decode = jax.jit(engine_lib.make_decode_step(cfg, enc))

        t_pre = _time(lambda: prefill(params, toks, caches)[0])
        rows.append((f"table2/prefill_tok_s/{label}", prefill_len / t_pre))

        _, caches2 = prefill(params, toks, caches)
        tok = jnp.ones((1, 1), jnp.int32)

        def dec_loop():
            c = caches2
            t = tok
            for i in range(decode_steps):
                t, _, c = decode(params, c, t, jnp.asarray(prefill_len + i - 1, jnp.int32))
            return t

        t_dec = _time(dec_loop)
        rows.append((f"table2/decode_tok_s/{label}", decode_steps / t_dec))
    return rows


def op_level_throughput(d_model: int = 1024, d_ff: int = 4096, batch: int = 1):
    """Per-matmul decode GEMV: the paper's core claim at op granularity.

    naive repacks the weight every call (what a runtime without device
    encodings does); mmt4d packs once at load."""
    rows = []
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(batch, d_model), jnp.float32)
    w_kn = jnp.asarray(rng.randn(d_model, d_ff), jnp.float32)   # (K, N) layout
    w_nk = jnp.asarray(w_kn.T)                                   # (N, K) layout
    rhs4 = ops.pack_rhs(w_nk)                                    # packed once

    @jax.jit
    def naive(x, w_kn):
        rhs = ref.pack(w_kn.T, (128, 128))  # per-call transpose + pack
        return ops.encoded_matmul(x, rhs, n=d_ff, phase=Phase.DECODE,
                                  backend="xla", out_dtype=jnp.float32)

    @jax.jit
    def reference(x, w_nk):
        return ref.matmul_reference(x, w_nk)

    @jax.jit
    def mmt4d(x, rhs4):
        return ops.encoded_matmul(x, rhs4, n=d_ff, phase=Phase.DECODE,
                                  backend="xla", out_dtype=jnp.float32)

    t_naive = _time(naive, x, w_kn)
    t_ref = _time(reference, x, w_nk)
    t_mmt = _time(mmt4d, x, rhs4)
    rows.append(("table2/op_decode_us/naive_repack", t_naive * 1e6))
    rows.append(("table2/op_decode_us/reference", t_ref * 1e6))
    rows.append(("table2/op_decode_us/mmt4d_prepacked", t_mmt * 1e6))
    rows.append(("table2/op_decode_speedup_vs_naive", t_naive / t_mmt))
    return rows


# ---- decode fast path (fused GEMV + position-vectorized engine) ------------


def _engine_decode_tok_s(
    params, cfg, enc, *, decode_mode, prompts, timed_steps
):
    """Steady-state decode tokens/s with every slot active (skewed positions).

    Returns (tok_s, decode_calls_per_step)."""
    eng = engine_lib.Engine(
        params, cfg, enc,
        slots=len(prompts),
        max_seq=max(len(p) for p in prompts) + timed_steps + 4,
        decode_mode=decode_mode,
        cache_mode="dense",   # this bench isolates dispatch vectorization
    )
    for i, p in enumerate(prompts):
        eng.submit(engine_lib.Request(uid=i, prompt=p, max_new_tokens=timed_steps + 2))
    eng.step()  # admit + first decode: compile outside the timed region
    eng.decode_fn = engine_lib.count_calls(eng.decode_fn)
    jax.block_until_ready(jax.tree.leaves(eng.caches)[0])
    t0 = time.perf_counter()
    emitted = 0
    for _ in range(timed_steps):
        emitted += eng.step()
    jax.block_until_ready(jax.tree.leaves(eng.caches)[0])
    dt = time.perf_counter() - t0
    return emitted / dt, eng.decode_fn.calls / timed_steps


def decode_fastpath_bench(
    arch: str = "qwen2-1.5b",
    *,
    quick: bool = False,
    out_json: str = "BENCH_decode.json",
):
    """Decode-path comparison for the paper's headline regime:

      op level   : unfused (pack -> GEMV -> unpack) vs fused GEMV, wall time
                   (interpret-mode Pallas on CPU — directional) + the TPU HBM
                   traffic model (exact bytes, core/encoding.py).
      engine     : grouped (per-position-group dispatch loop) vs vectorized
                   (one jitted decode per step) tokens/s under skewed prompt
                   lengths — real wall-clock on any backend.

    Emits BENCH_decode.json and returns the CSV rows."""
    rows = []
    result: dict = {"meta": {
        "arch": arch,
        "mode": "quick" if quick else "full",
        "note": (
            "tok_s/us are CPU wall-clock (op timings run interpret-mode "
            "Pallas); hbm_bytes_* are the TPU traffic model"
        ),
    }}

    # --- engine: grouped vs vectorized under position skew ---
    cfg = registry.get_reduced(arch)
    enc = EncodingConfig(enabled=True, backend="xla")
    params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
    rng = np.random.RandomState(0)
    plens = [3, 5, 7, 9]  # all distinct: grouped pays one dispatch per slot
    prompts = [rng.randint(1, cfg.vocab_size, n).astype(np.int32) for n in plens]
    timed_steps = 4 if quick else 16
    eng_stats = {}
    for mode in ("grouped", "vectorized"):
        tok_s, calls = _engine_decode_tok_s(
            params, cfg, enc, decode_mode=mode, prompts=prompts,
            timed_steps=timed_steps,
        )
        eng_stats[mode] = {"tok_s": tok_s, "decode_calls_per_step": calls}
        rows.append((f"decode/engine_tok_s/{mode}", tok_s))
        rows.append((f"decode/engine_calls_per_step/{mode}", calls))
    eng_stats["vectorized_vs_grouped_speedup"] = (
        eng_stats["vectorized"]["tok_s"] / eng_stats["grouped"]["tok_s"]
    )
    eng_stats["prompt_lens"] = plens
    eng_stats["timed_steps"] = timed_steps
    rows.append(
        ("decode/engine_vectorized_speedup", eng_stats["vectorized_vs_grouped_speedup"])
    )
    result["engine"] = eng_stats

    # --- op level: fused vs unfused decode GEMV ---
    m = len(plens)
    n, k = (512, 256) if quick else (2048, 1024)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    w_t = jnp.asarray(rng.randn(n, k), jnp.float32)
    rhs4 = ops.pack_rhs(w_t)
    rhs4_q, s_w = ops.pack_rhs_q8(w_t)
    rhs4_p4, s_w4 = ops.pack_rhs_q4(w_t)
    iters = 1 if quick else 3

    def unfused(a):
        return ops.encoded_matmul(
            a, rhs4, n=n, phase=Phase.DECODE, backend="pallas",
            out_dtype=jnp.float32, interpret=True,
        )

    def fused(a):
        return ops.encoded_matmul(
            a, rhs4, n=n, phase=Phase.DECODE, backend="fused",
            out_dtype=jnp.float32, interpret=True,
        )

    def q8_unfused(a):
        return ops.encoded_matmul_q8(
            a, rhs4_q, s_w, n=n, phase=Phase.DECODE, backend="pallas",
            out_dtype=jnp.float32, interpret=True,
        )

    def q8_fused(a):
        return ops.encoded_matmul_q8(
            a, rhs4_q, s_w, n=n, phase=Phase.DECODE, backend="fused",
            out_dtype=jnp.float32, interpret=True,
        )

    t_unf = _time(unfused, x, iters=iters, warmup=1)
    t_fus = _time(fused, x, iters=iters, warmup=1)
    t_q8u = _time(q8_unfused, x, iters=iters, warmup=1)
    t_q8f = _time(q8_fused, x, iters=iters, warmup=1)
    # Itemsizes match the f32 operands timed above (kernel_bench agrees).
    hbm = decode_projection_hbm_bytes(m, n, k, act_itemsize=4, weight_itemsize=4)
    op_stats = {
        "m": m, "n": n, "k": k,
        "unfused_us": t_unf * 1e6,
        "fused_us": t_fus * 1e6,
        "fused_vs_unfused_speedup": t_unf / t_fus,
        "q8_unfused_us": t_q8u * 1e6,
        "q8_fused_us": t_q8f * 1e6,
        "q8_fused_vs_unfused_speedup": t_q8u / t_q8f,
        "hbm_bytes_unfused": hbm["unfused"],
        "hbm_bytes_fused": hbm["fused"],
        "hbm_bytes_saved": hbm["saved"],
        "hbm_savings_frac": hbm["saved"] / hbm["unfused"],
    }
    result["op"] = op_stats
    for key in ("unfused_us", "fused_us", "fused_vs_unfused_speedup",
                "q8_fused_vs_unfused_speedup", "hbm_bytes_saved",
                "hbm_savings_frac"):
        rows.append((f"decode/op_{key}", op_stats[key]))

    # --- quant ladder: bf16 vs w8a8 vs w4a8 at equal batch ---
    # Wall-clock is interpret-mode-directional only; the decision row is the
    # weight-stream roofline (deterministic TPU traffic model): decode re-reads
    # every weight byte per token, so model tok/s ∝ 1/weight_stream_bytes.
    def q4_fused(a):
        return ops.encoded_matmul_q4(
            a, rhs4_p4, s_w4, n=n, phase=Phase.DECODE, backend="fused",
            out_dtype=jnp.float32, interpret=True,
        )

    t_q4f = _time(q4_fused, x, iters=iters, warmup=1)
    group = ref.Q4_GROUP
    stream = {
        "bf16": encoding.quant_weight_stream_bytes(n, k, quant="none"),
        "w8a8": encoding.quant_weight_stream_bytes(n, k, quant="w8a8"),
        "w4a8": encoding.quant_weight_stream_bytes(
            n, k, quant="w4a8", group=group,
            scale_itemsize=jnp.dtype(s_w4.dtype).itemsize,
        ),
    }
    model_tok_s = {
        q: encoding.decode_weight_stream_tok_s(b) for q, b in stream.items()
    }
    quant_stats = {
        "m": m, "n": n, "k": k, "group": group,
        "q8_fused_us": op_stats["q8_fused_us"],
        "q4_fused_us": t_q4f * 1e6,
        "weight_stream_bytes": stream,
        "model_tok_s": model_tok_s,
        "w4a8_vs_w8a8_model_tok_s_ratio": (
            model_tok_s["w4a8"] / model_tok_s["w8a8"]
        ),
        "w4a8_vs_bf16_model_tok_s_ratio": (
            model_tok_s["w4a8"] / model_tok_s["bf16"]
        ),
    }
    result["quant"] = quant_stats
    rows.append(("decode/quant_w4a8_model_tok_s", model_tok_s["w4a8"]))
    rows.append(("decode/quant_w8a8_model_tok_s", model_tok_s["w8a8"]))
    rows.append((
        "decode/quant_w4a8_vs_w8a8_tok_s_ratio",
        quant_stats["w4a8_vs_w8a8_model_tok_s_ratio"],
    ))
    rows.append(("decode/quant_w4a8_fused_us", quant_stats["q4_fused_us"]))

    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    return rows


# ---- attention op class: fused paged decode vs gather fallback -------------


def attention_bench(
    *,
    quick: bool = False,
    out_json: str = "BENCH_decode.json",
):
    """The attention-kernel headline (kernels/attn.py): decode-attention HBM
    bytes per token, fused paged kernel vs the `paged_gather` fallback.

      bytes model : deterministic TPU traffic (encoding.decode_attn_hbm_bytes)
                    at 4k context on llama3.2-1b-class KV geometry — the
                    CI-gated ratio (fused <= 0.5x gather) plus the short-
                    context rows showing the bounded-fallback win.
      parity      : paged kernel vs the jnp reference path on a randomized
                    GQA/ragged-position case, dense kernel vs reference, and
                    paged-vs-dense bit-consistency at matched granularity —
                    parity == 1.0 is CI-gated.
      crossover   : context length where fused attention traffic overtakes
                    the w4a8 weight stream (the "attention is the next
                    roofline" number, docs/PERF.md).

    Merges an "attn" section into BENCH_decode.json and returns CSV rows."""
    from repro.kernels import attn as attn_lib
    from repro.kernels import registry as registry_lib
    from repro.models import layers as L

    # llama3.2-1b-class KV geometry (full-size, for the traffic model).
    kvh, hd, layers, itemsize = 8, 64, 16, 2
    ctx, max_seq, bs = 4096, 4096, 16
    model_4k = encoding.decode_attn_hbm_bytes(
        ctx, max_seq=max_seq, block_size=bs, num_kv_heads=kvh, head_dim=hd,
        num_layers=layers, itemsize=itemsize,
    )
    model_short = encoding.decode_attn_hbm_bytes(
        256, max_seq=max_seq, block_size=bs, num_kv_heads=kvh, head_dim=hd,
        num_layers=layers, itemsize=itemsize,
    )
    # w4a8 weight stream of the same model class: every projection byte per
    # token (param_count ~ 1.1e9 at full size; use the projection total).
    w4a8_bytes = encoding.quant_weight_stream_bytes(1, 1_100_000_000, quant="w4a8")
    crossover = encoding.attn_weight_crossover_tokens(
        w4a8_bytes, num_kv_heads=kvh, head_dim=hd, num_layers=layers,
        itemsize=itemsize,
    )

    # Kernel parity on a randomized reduced case (interpret-mode Pallas).
    rng = np.random.RandomState(0)
    b, L_q, h, kv, d, pbs, nb = 3, 2, 8, 2, 16, 8, 4
    pool_shape = (1 + b * nb, pbs, kv, d)
    k_pool = jnp.asarray(rng.randn(*pool_shape), jnp.float32)
    v_pool = jnp.asarray(rng.randn(*pool_shape), jnp.float32)
    table = jnp.asarray(1 + rng.permutation(b * nb).reshape(b, nb), jnp.int32)
    q = jnp.asarray(rng.randn(b, L_q, h, d), jnp.float32)
    pos = jnp.asarray(rng.randint(0, nb * pbs - L_q + 1, b), jnp.int32)

    t_paged = _time(
        lambda: attn_lib.paged_decode_attention(
            q, k_pool, v_pool, table, pos, interpret=True
        ),
        iters=1 if quick else 3, warmup=1,
    )
    got = attn_lib.paged_decode_attention(
        q, k_pool, v_pool, table, pos, interpret=True
    )
    gathered_k = L.paged_gather(k_pool, table)
    gathered_v = L.paged_gather(v_pool, table)
    t_gather = _time(
        lambda: L.attention_decode(
            q, L.paged_gather(k_pool, table), L.paged_gather(v_pool, table),
            pos=pos, window=0,
        ),
        iters=1 if quick else 3, warmup=1,
    )
    want = L.attention_decode(q, gathered_k, gathered_v, pos=pos, window=0)
    err_paged = float(jnp.max(jnp.abs(got - want)))
    dense_kernel = attn_lib.dense_decode_attention(
        q, gathered_k, gathered_v, pos, kv_chunk=pbs, interpret=True
    )
    err_dense = float(jnp.max(jnp.abs(dense_kernel - want)))
    bit_consistent = bool(jnp.all(got == dense_kernel))
    parity = 1.0 if (err_paged < 1e-4 and err_dense < 1e-4 and bit_consistent) else 0.0

    choice = registry_lib.select_attn(
        phase=encoding.Phase.DECODE, s=max_seq, requested="auto"
    )
    attn_stats = {
        "kv_geometry": {
            "num_kv_heads": kvh, "head_dim": hd, "num_layers": layers,
            "itemsize": itemsize, "block_size": bs, "max_seq": max_seq,
        },
        "hbm_bytes_per_token_4k": model_4k,
        "hbm_bytes_per_token_256": model_short,
        "paged_bytes_ratio_4k": model_4k["ratio"],
        "bounded_fallback_ratio_256": (
            model_short["bounded_gather"] / model_short["gather"]
        ),
        "w4a8_weight_stream_bytes": w4a8_bytes,
        "attn_weight_crossover_tokens": crossover,
        "kernel_parity": parity,
        "paged_vs_dense_bit_consistent": 1.0 if bit_consistent else 0.0,
        "max_abs_err_paged": err_paged,
        "max_abs_err_dense": err_dense,
        "paged_kernel_us": t_paged * 1e6,
        "gather_reference_us": t_gather * 1e6,
        "registry_backend_4k": choice.backend,
        "registry_source_4k": choice.source,
    }
    try:
        with open(out_json) as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {}
    result["attn"] = attn_stats
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    return [
        ("attn/paged_bytes_ratio_4k", attn_stats["paged_bytes_ratio_4k"]),
        ("attn/fused_mb_per_token_4k", model_4k["fused"] / 1e6),
        ("attn/gather_mb_per_token_4k", model_4k["gather"] / 1e6),
        ("attn/crossover_tokens_vs_w4a8", crossover),
        ("attn/kernel_parity", parity),
        ("attn/paged_kernel_us", attn_stats["paged_kernel_us"]),
    ]


# ---- speculative decode: prompt-lookup draft + batched verify --------------


def spec_decode_bench(
    arch: str = "qwen2-1.5b",
    *,
    quick: bool = False,
    out_json: str = "BENCH_decode.json",
):
    """Speculative decode on a repetition-heavy workload (the regime
    prompt-lookup drafting targets: templated/loopy continuations — here the
    reduced model's own greedy cycle, which the drafter reads out of the
    generated history).

    Headline metric: measured decode DISPATCHES per generated token —
    (decode_fn + verify_fn calls) / tokens on a single slot.  In the paper's
    memory-bound decode regime every dispatch re-streams the full weight set,
    so model tok/s scales as its inverse (docs/PERF.md §Speculative decode);
    CPU wall-clock is reported but not gated (interpret-mode CPU is
    compute-bound — the verify's extra FLOPs are ~free on TPU, not here).

    Merges a "spec" section into BENCH_decode.json (decode_fastpath_bench
    writes the file first) and returns CSV rows."""
    cfg = registry.get_reduced(arch)
    enc = EncodingConfig(enabled=True, backend="xla")
    params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
    rng = np.random.RandomState(0)
    phrase = rng.randint(1, cfg.vocab_size, 4).astype(np.int32)
    prompt = np.tile(phrase, 8)
    # Long enough that the greedy cycle dominates the drafter's warmup (the
    # first ~30 tokens are incompressible); quick mode keeps the same length
    # because the metric, not the wall-clock, is the point.
    max_new, draft_k = 96, 6
    runs = {}
    gens = {}
    for label, spec in (("plain", False), ("spec", True)):
        eng = engine_lib.Engine(
            params, cfg, enc, slots=1, max_seq=160,
            spec_decode=spec, draft_k=draft_k,
        )
        eng.decode_fn = engine_lib.count_calls(eng.decode_fn)
        if spec:
            eng.verify_fn = engine_lib.count_calls(eng.verify_fn)
        eng.submit(engine_lib.Request(uid=0, prompt=prompt, max_new_tokens=max_new))
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        gens[label] = done[0].generated
        tokens = len(done[0].generated)
        dispatches = eng.decode_fn.calls + (eng.verify_fn.calls if spec else 0)
        runs[label] = {
            "tokens": tokens,
            "dispatches": dispatches,
            "dispatches_per_token": dispatches / tokens,
            "tok_s_wall": tokens / dt,
        }
        if spec:
            st = eng.stats["spec"]
            runs[label].update(
                mean_accepted_len=st["mean_accepted_len"],
                acceptance_rate=st["acceptance_rate"],
                proposed=st["proposed"],
                accepted=st["accepted"],
            )
    identical = gens["spec"] == gens["plain"]
    spec_stats = {
        "arch": arch,
        "draft_k": draft_k,
        "max_new": max_new,
        "prompt_len": int(len(prompt)),
        "plain": runs["plain"],
        "dispatches_per_token": runs["spec"]["dispatches_per_token"],
        "mean_accepted_len": runs["spec"]["mean_accepted_len"],
        "acceptance_rate": runs["spec"]["acceptance_rate"],
        "tok_s_wall": runs["spec"]["tok_s_wall"],
        # Weight-stream projection: each dispatch re-reads every weight byte,
        # so memory-bound model tok/s scales with tokens per dispatch.
        "model_tok_s_uplift": 1.0 / runs["spec"]["dispatches_per_token"],
        "token_identical": 1.0 if identical else 0.0,
    }
    # Merge into the decode-bench JSON (decode_fastpath_bench ran first).
    try:
        with open(out_json) as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {}
    result["spec"] = spec_stats
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    return [
        ("spec/dispatches_per_token", spec_stats["dispatches_per_token"]),
        ("spec/mean_accepted_len", spec_stats["mean_accepted_len"]),
        ("spec/acceptance_rate", spec_stats["acceptance_rate"]),
        ("spec/model_tok_s_uplift", spec_stats["model_tok_s_uplift"]),
        ("spec/token_identical", spec_stats["token_identical"]),
    ]


# ---- chaos conformance + guard overhead ------------------------------------


def chaos_bench(
    arch: str = "qwen2-1.5b",
    *,
    quick: bool = False,
    out_json: str = "BENCH_decode.json",
):
    """Robustness gates (docs/ROBUSTNESS.md), as bench numbers:

      token_identical_under_faults — 1.0 iff every request that SURVIVES the
          committed adversarial fault schedule (tests/fault_schedules/
          mixed_paged.json) emits exactly the fault-free run's tokens.
          Gated at 1.0: faults may kill requests, never corrupt neighbours.
      pages_leaked — pool pages still held once the faulted stream drains.
          Gated at 0: every lifecycle exit path frees through the allocator.
      guard_overhead_frac — wall-clock cost of the per-step non-finite
          logits guard (guarded / unguarded - 1 on a clean decode stream).
          Reported, not gated (CPU wall-clock; the guard is one (B,) device
          reduction + transfer per step) — cited by docs/ROBUSTNESS.md.

    Merges a "chaos" section into BENCH_decode.json and returns CSV rows."""
    cfg = registry.get_reduced(arch)
    enc = EncodingConfig(enabled=True, backend="xla")
    params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
    rng = np.random.RandomState(0)
    n_req = 4 if quick else 6
    max_new = 6 if quick else 10
    prompts = [
        rng.randint(1, cfg.vocab_size, rng.randint(4, 10)).astype(np.int32)
        for _ in range(n_req)
    ]

    def run(hooks=None, *, guard=True):
        eng = engine_lib.Engine(
            params, cfg, enc, slots=3, max_seq=64,
            fault_hooks=hooks,
            clock=(hooks.clock if hooks is not None else None),
            logits_guard=guard,
        )
        for i, p in enumerate(prompts):
            assert eng.submit(
                engine_lib.Request(uid=i, prompt=p, max_new_tokens=max_new)
            )
        steps = 0
        t0 = time.perf_counter()
        while eng.queue or any(r is not None for r in eng.slot_req):
            assert steps < 400, "chaos bench deadlocked"
            eng.step()
            steps += 1
        dt = time.perf_counter() - t0
        if hooks is not None:
            hooks.drain(eng)
        eng.audit()
        return eng, dt

    # The quarantine is process-global; isolate this bench's demotions.
    kernel_registry.clear_quarantine()
    gold_eng, _ = run()
    gold = {r.uid: list(r.generated) for r in gold_eng.finished}
    sched_path = os.path.join(
        os.path.dirname(os.path.abspath(__file__)),
        "..", "tests", "fault_schedules", "mixed_paged.json",
    )
    sched = faults_lib.FaultSchedule.from_json(sched_path)
    eng, _ = run(sched)
    survivors = [r for r in eng.finished if r.status == "ok"]
    identical = all(list(r.generated) == gold[r.uid] for r in survivors)
    leaked = eng.alloc.in_use()
    kernel_registry.clear_quarantine()

    # Guard overhead on a clean stream: jit caches are warm after the runs
    # above, so the delta is the guard's own reduction + host transfer.
    _, t_guard = run(guard=True)
    _, t_noguard = run(guard=False)
    overhead = t_guard / max(t_noguard, 1e-9) - 1.0

    chaos_stats = {
        "arch": arch,
        "mode": "quick" if quick else "full",
        "schedule": "tests/fault_schedules/mixed_paged.json",
        "requests": n_req,
        "survivors": len(survivors),
        "statuses": {r.uid: r.status for r in eng.finished},
        "token_identical_under_faults": 1.0 if identical else 0.0,
        "pages_leaked": float(leaked),
        "degraded_keys": len(eng.stats["degraded"]),
        "lifecycle": eng.stats["lifecycle"],
        "watchdog": eng.stats["watchdog"],
        "guard_overhead_frac": overhead,
    }
    try:
        with open(out_json) as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {}
    result["chaos"] = chaos_stats
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    return [
        ("chaos/token_identical_under_faults",
         chaos_stats["token_identical_under_faults"]),
        ("chaos/pages_leaked", chaos_stats["pages_leaked"]),
        ("chaos/survivors", float(len(survivors))),
        ("chaos/degraded_keys", float(chaos_stats["degraded_keys"])),
        ("chaos/guard_overhead_frac", overhead),
    ]


# ---- continuous batching: token-budget mixed prefill+decode ----------------


def continuous_bench(
    arch: str = "qwen2-1.5b",
    *,
    quick: bool = False,
    out_json: str = "BENCH_decode.json",
):
    """Token-budget continuous batching (serving/engine.py): a LONG prompt is
    admitted while two short requests are mid-decode, and the whole stream
    runs through the unified mixed chunked-prefill + decode dispatch.

      decode_stall_steps — steps where a live decoding slot emitted nothing
          (the metric the scheduler exists for).  Gated at 0: the budget
          reserves a 1-token floor per decode row before any chunk is
          packed, so prefill NEVER pauses decode.
      token_identical — 1.0 iff every request (the long one included) emits
          exactly what the phase-split engine emits on the same arrival
          pattern.  Gated at 1.0.
      pages_leaked — pool pages still held after drain.  Gated at 0.
      p99_step_ms_* — per-step wall clock, mixed vs phase-split.  The
          phase-split engine prefills the long prompt in ONE dispatch, so
          its tail step is the whole prefill; the mixed engine's steps are
          budget-bounded.  Reported, not gated (CPU wall clock, compiles
          included) — cited by docs/PERF.md §Token-budget scheduling.

    Merges a "continuous" section into BENCH_decode.json, returns CSV rows."""
    cfg = registry.get_reduced(arch)
    enc = EncodingConfig(enabled=True, backend="xla")
    params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
    rng = np.random.RandomState(0)
    long_len = 256 if quick else 4096
    budget = 32 if quick else 128
    # Shorts must still be decoding when the long prefill finishes, or the
    # stall gate would have nothing to measure: prefill takes about
    # long_len / (budget - decode_rows) mixed steps.
    max_new_short = 24 if quick else 48
    shorts = [
        rng.randint(1, cfg.vocab_size, 8).astype(np.int32) for _ in range(2)
    ]
    long_p = rng.randint(1, cfg.vocab_size, long_len).astype(np.int32)
    max_seq = long_len + 64

    def run(token_budget):
        eng = engine_lib.Engine(
            params, cfg, enc, slots=3, max_seq=max_seq,
            cache_mode="paged", block_size=16, token_budget=token_budget,
        )
        for i, p in enumerate(shorts):
            assert eng.submit(
                engine_lib.Request(uid=i, prompt=p, max_new_tokens=max_new_short)
            )
        step_ms: list[float] = []
        steps = 0
        while eng.queue or any(r is not None for r in eng.slot_req):
            assert steps < 4000, "continuous bench deadlocked"
            if steps == 2:  # long prompt arrives mid-decode
                assert eng.submit(
                    engine_lib.Request(uid=9, prompt=long_p, max_new_tokens=4)
                )
            t0 = time.perf_counter()
            eng.step()
            step_ms.append((time.perf_counter() - t0) * 1e3)
            steps += 1
        eng.audit()
        return eng, np.asarray(step_ms)

    kernel_registry.clear_quarantine()
    split_eng, split_ms = run(None)
    gold = {r.uid: list(r.generated) for r in split_eng.finished}
    mix_eng, mix_ms = run(budget)
    got = {r.uid: list(r.generated) for r in mix_eng.finished}
    identical = got == gold
    kernel_registry.clear_quarantine()

    c = mix_eng.stats["continuous"]
    cont_stats = {
        "arch": arch,
        "mode": "quick" if quick else "full",
        "token_budget": budget,
        "long_prompt_len": long_len,
        "decode_stall_steps": float(c["decode_stall_steps"]),
        "token_identical": 1.0 if identical else 0.0,
        "pages_leaked": float(mix_eng.alloc.in_use()),
        "mixed_steps": c["mixed_steps"],
        "chunked_admissions": c["chunked_admissions"],
        "completed_prefills": c["completed_prefills"],
        "prefill_tokens": c["prefill_tokens"],
        "decode_tokens": c["decode_tokens"],
        "steps_mixed": int(mix_ms.size),
        "steps_split": int(split_ms.size),
        "p99_step_ms_mixed": float(np.percentile(mix_ms, 99)),
        "p99_step_ms_split": float(np.percentile(split_ms, 99)),
        "max_step_ms_mixed": float(mix_ms.max()),
        "max_step_ms_split": float(split_ms.max()),
    }
    try:
        with open(out_json) as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {}
    result["continuous"] = cont_stats
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    return [
        ("continuous/decode_stall_steps", cont_stats["decode_stall_steps"]),
        ("continuous/token_identical", cont_stats["token_identical"]),
        ("continuous/pages_leaked", cont_stats["pages_leaked"]),
        ("continuous/p99_step_ms_mixed", cont_stats["p99_step_ms_mixed"]),
        ("continuous/p99_step_ms_split", cont_stats["p99_step_ms_split"]),
    ]


# ---- tensor parallelism: sharded serving over a device mesh ----------------


_TP_BENCH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys, time
import jax
import numpy as np
from repro.configs import registry
from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.serving import engine as engine_lib
from repro.serving.config import EngineConfig

quick = sys.argv[1] == "quick"
# num_kv_heads=4 so the KV-head axis divides at 2 and 4 shards (the stock
# reduced config's single KV head would replicate — correct, no capacity win).
cfg = registry.get_reduced("qwen2-1.5b", num_kv_heads=4)
enc = EncodingConfig(enabled=True, backend="xla")
params = T.model_init(jax.random.PRNGKey(0), cfg, enc)
rng = np.random.RandomState(0)
prompts = [rng.randint(1, cfg.vocab_size, n).astype(np.int32)
           for n in (5, 8, 11, 14)]
max_new = 6 if quick else 12

def run(shards):
    eng = engine_lib.Engine(
        params, cfg, enc,
        config=EngineConfig(slots=len(prompts), max_seq=64,
                            cache_mode="paged", block_size=8,
                            mesh_shape=(shards,)))
    for i, p in enumerate(prompts):
        eng.submit(engine_lib.Request(uid=i, prompt=p, max_new_tokens=max_new))
    eng.step()  # admit + first decode: compile outside the timed region
    t0 = time.perf_counter()
    emitted = 0
    while eng.queue or any(r is not None for r in eng.slot_req):
        emitted += eng.step()
    jax.block_until_ready(jax.tree.leaves(eng.caches)[0])
    dt = time.perf_counter() - t0
    eng.audit()
    return {r.uid: list(r.generated) for r in eng.finished}, emitted / dt

out = {}
base = None
for shards in (1, 2, 4):
    gens, tok_s = run(shards)
    if base is None:
        base = gens
    out[str(shards)] = {"tok_s": tok_s,
                        "token_identical": 1.0 if gens == base else 0.0}
print("TP_BENCH_JSON " + json.dumps(out))
"""


def tp_bench(
    *,
    quick: bool = False,
    out_json: str = "BENCH_decode.json",
):
    """Tensor-parallel serving (docs/PERF.md §Tensor-parallel capacity math):

      token_identical     — mesh=2/4 decode emits exactly the mesh=1 stream
                            (4 emulated CPU devices in a subprocess; the
                            same CI-gated identity tests/test_tp_mesh.py
                            pins).  Gated at 1.0.
      kv_capacity_scaling — analytic paged request capacity at a FIXED
                            per-shard HBM budget, relative to 1 shard
                            (encoding.tp_kv_capacity_requests): head-parallel
                            KV shrinks each shard's bytes/token by the shard
                            count, so capacity scales with shards when the
                            kv heads divide.  Gated >= 1.8 at 2 shards.
      tok_s               — emulated-CPU wall clock per shard count.
                            Directional only (host devices share one core);
                            reported, not gated.

    Merges a "tp" section into BENCH_decode.json and returns CSV rows."""
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    env.pop("XLA_FLAGS", None)  # the child pins its own device count
    r = subprocess.run(
        [sys.executable, "-c", _TP_BENCH_SCRIPT, "quick" if quick else "full"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    if r.returncode != 0:
        raise RuntimeError(f"tp bench subprocess failed:\n{r.stderr[-4000:]}")
    line = next(
        l for l in r.stdout.splitlines() if l.startswith("TP_BENCH_JSON ")
    )
    measured = json.loads(line[len("TP_BENCH_JSON "):])

    # Analytic capacity at one fixed per-shard budget (full-size llama3.2-1b
    # KV geometry: 8 kv heads x 64 head_dim x 16 layers, bf16).
    kvh, hd, layers, itemsize = 8, 64, 16, 2
    max_seq, block_size, mean_tokens = 4096, 16, 512
    budget = encoding.dense_kv_hbm_bytes(
        4, max_seq, layers, kvh, hd, itemsize=itemsize
    )
    capacity = {
        str(s): encoding.tp_kv_capacity_requests(
            budget, shards=s, max_seq=max_seq, mean_tokens=mean_tokens,
            block_size=block_size, num_layers=layers, num_kv_heads=kvh,
            head_dim=hd, itemsize=itemsize,
        )
        for s in (1, 2, 4)
    }
    token_identical = min(
        measured[s]["token_identical"] for s in ("1", "2", "4")
    )
    tp_stats = {
        "mode": "quick" if quick else "full",
        "emulation": "--xla_force_host_platform_device_count=4",
        "kv_geometry": {
            "num_kv_heads": kvh, "head_dim": hd, "num_layers": layers,
            "itemsize": itemsize, "max_seq": max_seq,
            "block_size": block_size, "mean_tokens": mean_tokens,
        },
        "hbm_budget_per_shard": int(budget),
        "shards": {
            s: {
                "tok_s": measured[s]["tok_s"],
                "token_identical": measured[s]["token_identical"],
                "capacity_requests": capacity[s]["paged"],
                "bytes_per_token_per_shard":
                    capacity[s]["bytes_per_token_per_shard"],
            }
            for s in ("1", "2", "4")
        },
        "token_identical": token_identical,
        "kv_capacity_scaling_2": capacity["2"]["scaling_vs_1"],
        "kv_capacity_scaling_4": capacity["4"]["scaling_vs_1"],
    }
    try:
        with open(out_json) as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {}
    result["tp"] = tp_stats
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    return [
        ("tp/token_identical", token_identical),
        ("tp/kv_capacity_scaling_2", tp_stats["kv_capacity_scaling_2"]),
        ("tp/kv_capacity_scaling_4", tp_stats["kv_capacity_scaling_4"]),
        ("tp/capacity_requests_1", capacity["1"]["paged"]),
        ("tp/capacity_requests_2", capacity["2"]["paged"]),
        ("tp/capacity_requests_4", capacity["4"]["paged"]),
        ("tp/tok_s_1", measured["1"]["tok_s"]),
        ("tp/tok_s_2", measured["2"]["tok_s"]),
        ("tp/tok_s_4", measured["4"]["tok_s"]),
    ]


# ---- paged KV cache: pool utilization + capacity vs dense ------------------


def paged_cache_bench(
    arch: str = "qwen2-1.5b",
    *,
    quick: bool = False,
    out_json: str = "BENCH_paged.json",
):
    """The serving memory plan's headline: under ONE KV HBM budget, how many
    requests can be in flight at once?

      dense  — every slot reserves (max_seq) tokens; capacity = budget /
               (max_seq * bytes_per_token).
      paged  — slots hold ceil(len/block) pages; capacity scales with tokens
               actually in flight.  Measured by running both engines on the
               same short-prompt stream and recording peak concurrency, pool
               utilization, prefix-reuse hits, and preemptions.

    Emits BENCH_paged.json and returns CSV rows."""
    cfg = registry.get_reduced(arch)
    enc = EncodingConfig(enabled=True, backend="xla")
    params = T.model_init(jax.random.PRNGKey(0), cfg, enc)

    max_seq = 64 if quick else 128
    block_size = 8
    dense_slots = 2 if quick else 4
    ptb = encoding.kv_bytes_per_token(
        cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
        itemsize=jnp.dtype(cfg.activation_dtype).itemsize,
    )
    hbm_budget = encoding.dense_kv_hbm_bytes(
        dense_slots, max_seq, cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
        itemsize=jnp.dtype(cfg.activation_dtype).itemsize,
    )
    pool_pages = hbm_budget // (block_size * ptb)  # same budget, page-granular
    paged_slots = min(int(pool_pages), 8 if quick else 12)

    rng = np.random.RandomState(0)
    n_req = 8 if quick else 16
    max_new = 6 if quick else 10
    common = rng.randint(1, cfg.vocab_size, 8).astype(np.int32)

    def stream():
        reqs = []
        for i in range(n_req):
            plen = int(rng.randint(4, 13))
            p = rng.randint(1, cfg.vocab_size, plen).astype(np.int32)
            if i % 3 == 0:
                p = np.concatenate([common, p[:4]])  # shared prefix cohort
            reqs.append(engine_lib.Request(uid=i, prompt=p, max_new_tokens=max_new))
        return reqs

    def run(eng):
        for r in stream():
            eng.submit(r)
        util = []
        t0 = time.perf_counter()
        steps = 0
        while eng.queue or any(r is not None for r in eng.slot_req):
            eng.step()
            steps += 1
            if eng.cache_mode == "paged":
                util.append(eng.alloc.in_use() / eng.alloc.capacity)
            assert steps < 5000
        dt = time.perf_counter() - t0
        tokens = sum(len(r.generated) for r in eng.finished)
        return tokens / dt, steps, util

    rng = np.random.RandomState(0)
    eng_d = engine_lib.Engine(
        params, cfg, enc, slots=dense_slots, max_seq=max_seq, cache_mode="dense"
    )
    dense_tok_s, dense_steps, _ = run(eng_d)
    dense_peak = dense_slots  # a dense engine is concurrency-capped at slots

    rng = np.random.RandomState(0)
    eng_p = engine_lib.Engine(
        params, cfg, enc, slots=paged_slots, max_seq=max_seq,
        cache_mode="paged", block_size=block_size, pool_pages=int(pool_pages),
    )
    paged_tok_s, paged_steps, util = run(eng_p)
    stats = eng_p.stats

    cap = encoding.kv_capacity_requests(
        hbm_budget, max_seq=max_seq, mean_tokens=16 + max_new,
        block_size=block_size, num_layers=cfg.num_layers,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        itemsize=jnp.dtype(cfg.activation_dtype).itemsize,
    )
    result = {
        "meta": {
            "arch": arch, "mode": "quick" if quick else "full",
            "hbm_budget_bytes": int(hbm_budget),
            "bytes_per_token": int(ptb),
            "max_seq": max_seq, "block_size": block_size,
            "note": "one KV HBM budget; dense reserves worst-case rows, "
                    "paged allocates per-block (serving/paged.py)",
        },
        "concurrent_requests": {
            "dense": dense_peak,
            "paged_peak": stats["peak_active"],
            "paged_vs_dense_ratio": stats["peak_active"] / dense_peak,
        },
        "analytic_capacity": cap,
        "dense": {"tok_s": dense_tok_s, "engine_steps": dense_steps},
        "paged": {
            "tok_s": paged_tok_s, "engine_steps": paged_steps,
            "pool_pages": int(pool_pages),
            "pool_utilization_mean": float(np.mean(util)) if util else 0.0,
            "pool_utilization_peak": float(np.max(util)) if util else 0.0,
            "shared_hits": stats["shared_hits"],
            "cow_events": stats["cow_events"],
            "preemptions": stats["preemptions"],
        },
    }
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    rows = [
        ("paged/concurrent_dense", dense_peak),
        ("paged/concurrent_paged_peak", stats["peak_active"]),
        ("paged/concurrent_ratio", stats["peak_active"] / dense_peak),
        ("paged/pool_utilization_peak", result["paged"]["pool_utilization_peak"]),
        ("paged/shared_hits", stats["shared_hits"]),
        ("paged/preemptions", stats["preemptions"]),
        ("paged/tok_s", paged_tok_s),
        ("paged/dense_tok_s", dense_tok_s),
    ]
    return rows


def prefix_cache_bench(
    arch: str = "qwen2-1.5b",
    *,
    quick: bool = False,
    out_json: str = "BENCH_paged.json",
):
    """Radix-tree prefix cache (docs/PERF.md §Prefix caching) on a
    multi-tenant trace: 3 tenants, each with its own shared system prompt
    and a zipf-reused template library, plus a unique per-request tail.

      hit_rate        — block-level LCP hits / looked-up immutable blocks;
                        the radix tree must clear 0.5 on a trace where the
                        old exact-whole-prefix matcher (computed here as an
                        analytic oracle) scores < 0.1.
      token_identical — the same trace replayed cache-on / cache-off /
                        dense must generate identical tokens per request.
      pressure leg    — a small pool re-serves the trace so cumulative
                        demand fills it >= 3x: evictions must fire, audit()
                        stays exact every step, nothing leaks, and (with a
                        tenant_quota) no tenant's charged usage exceeds the
                        quota while another tenant has queued work.

    Merges a "prefix_cache" section into BENCH_paged.json and returns CSV
    rows; check_regression.py gates hit_rate, token_identical and
    pages_leaked."""
    cfg = registry.get_reduced(arch)
    enc = EncodingConfig(enabled=True, backend="xla")
    params = T.model_init(jax.random.PRNGKey(0), cfg, enc)

    max_seq = 96
    block_size = 8
    n_tenants = 3
    per_tenant = 4 if quick else 8
    max_new = 4 if quick else 8

    rng = np.random.RandomState(0)
    system = {t: rng.randint(1, cfg.vocab_size, 24).astype(np.int32)
              for t in range(n_tenants)}        # 3 full blocks each
    templates = {t: [rng.randint(1, cfg.vocab_size,
                                 8 * (1 + k % 2)).astype(np.int32)
                     for k in range(3)]
                 for t in range(n_tenants)}
    zipf = np.array([1.0, 0.5 ** 1.5, 1.0 / 3 ** 1.5])
    zipf /= zipf.sum()

    def trace():
        """The seeded multi-tenant request stream (tenants interleaved)."""
        r = np.random.RandomState(42)
        reqs = []
        for i in range(n_tenants * per_tenant):
            t = i % n_tenants
            tmpl = templates[t][int(r.choice(3, p=zipf))]
            tail = r.randint(1, cfg.vocab_size,
                             int(r.randint(8, 13))).astype(np.int32)
            reqs.append(engine_lib.Request(
                uid=i, max_new_tokens=max_new, tenant=f"tenant-{t}",
                prompt=np.concatenate([system[t], tmpl, tail]),
            ))
        return reqs

    # Analytic oracle for the OLD exact-whole-prefix matcher: a request's
    # immutable run hits only when that ENTIRE run was registered before.
    seen: set = set()
    exact_hits = exact_lookups = 0
    for req in trace():
        nshare = max(0, (len(req.prompt) - 1) // block_size)
        whole = tuple(int(x) for x in req.prompt[: nshare * block_size])
        if nshare:
            exact_lookups += nshare
            if whole in seen:
                exact_hits += nshare
            seen.add(whole)
    exact_whole_prefix_rate = exact_hits / max(1, exact_lookups)

    def run(**kw):
        eng = engine_lib.Engine(
            params, cfg, enc, slots=4, max_seq=max_seq,
            block_size=block_size, **kw,
        )
        quota = kw.get("tenant_quota")
        quota_violations = 0
        steps = 0
        for req in trace():
            assert eng.submit(req), f"uid {req.uid} rejected"
        while eng.queue or any(r is not None for r in eng.slot_req):
            eng.step()
            steps += 1
            assert steps < 5000
            if kw.get("cache_mode", "paged") == "paged":
                eng.audit()
                if quota is not None and eng.queue:
                    usage = eng.alloc.tenant_usage()
                    if any(u > quota + 1e-9 for u in usage.values()):
                        quota_violations += 1
        assert all(r.status == "ok" for r in eng.finished)
        toks = {r.uid: list(r.generated) for r in eng.finished}
        return eng, toks, quota_violations

    eng_on, gold, _ = run(cache_mode="paged", prefix_cache=True)
    pc = eng_on.stats["prefix_cache"]
    hit_rate = pc["hit_rate"]
    # The tentpole's acceptance bar, self-enforcing: LCP matching must clear
    # 0.5 on a trace where exact-whole-prefix matching is near-useless.
    assert hit_rate >= 0.5, f"radix hit rate {hit_rate:.3f} < 0.5"
    assert exact_whole_prefix_rate < 0.1, (
        f"trace too easy: exact matcher scores {exact_whole_prefix_rate:.3f}"
    )

    _, toks_off, _ = run(cache_mode="paged", prefix_cache=False)
    _, toks_dense, _ = run(cache_mode="dense")
    token_identical = 1.0 if (toks_off == gold and toks_dense == gold) else 0.0

    # Eviction-pressure leg: a pool several times smaller than the trace's
    # cumulative page demand, with a per-tenant quota.  Every step audits.
    pool_pages = 12 if quick else 18
    quota = 10
    eng_pr, toks_pr, violations = run(
        cache_mode="paged", prefix_cache=True, pool_pages=pool_pages,
        tenant_quota=quota, token_budget=32,
    )
    pr = eng_pr.stats
    fill_factor = pr["allocs"] / eng_pr.alloc.capacity
    assert fill_factor >= 3.0, (
        f"pressure leg refilled the pool only {fill_factor:.1f}x"
    )
    pages_leaked = float(eng_pr.alloc.in_use())
    eng_pr.audit()

    section = {
        "trace": {
            "tenants": n_tenants, "requests": n_tenants * per_tenant,
            "block_size": block_size,
            "note": "shared 16-token system prompt per tenant + zipf "
                    "template reuse + unique tails",
        },
        "hit_rate": hit_rate,
        "hit_blocks": pc["hit_blocks"],
        "hit_tokens": pc["hit_tokens"],
        "lookup_blocks": pc["lookup_blocks"],
        "exact_whole_prefix_rate": exact_whole_prefix_rate,
        "token_identical": token_identical,
        "pressure": {
            "pool_pages": pool_pages,
            "fill_factor": fill_factor,
            "evictions": pr["prefix_cache"]["evictions"],
            "deferred_hits": pr["prefix_cache"]["deferred_hits"],
            "cached_pages": pr["prefix_cache"]["cached_pages"],
            "preemptions": pr["preemptions"],
            "tenant_quota": quota,
            "quota_violations": violations,
            "token_identical": 1.0 if toks_pr == gold else 0.0,
        },
        "pages_leaked": pages_leaked,
        "quota_violations": float(violations),
    }
    try:
        with open(out_json) as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {}
    result["prefix_cache"] = section
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    return [
        ("prefix_cache/hit_rate", hit_rate),
        ("prefix_cache/exact_whole_prefix_rate", exact_whole_prefix_rate),
        ("prefix_cache/hit_tokens", pc["hit_tokens"]),
        ("prefix_cache/token_identical", token_identical),
        ("prefix_cache/evictions", section["pressure"]["evictions"]),
        ("prefix_cache/deferred_hits", section["pressure"]["deferred_hits"]),
        ("prefix_cache/quota_violations", float(violations)),
        ("prefix_cache/pages_leaked", pages_leaked),
    ]


def kv_quant_bench(
    arch: str = "qwen2-1.5b",
    *,
    quick: bool = False,
    out_json: str = "BENCH_decode.json",
    out_paged_json: str = "BENCH_paged.json",
):
    """Quantized paged KV cache (kv8): the capacity-for-accuracy headline.

      decision preservation — serve one seeded stream under bf16 and kv8;
        gold tokens teacher-forced back through the bf16 model give per-
        position top-2 margins, and kv8 must match gold at every CONFIDENT
        position (margin >= the median — the PR-3 margin-aware harness; a
        near-tie flipped by rounding is not a decision change).  The streams
        are free-running, so comparison stops at the first divergence: once
        a near-tie flips, the histories differ and later positions are not
        comparable.  A confident-position flip before any divergence fails
        the metric; the CI gate holds it at 1.0.
      relMSE — codec-level: decode-attention output on the dequantized kv8
        cache vs the raw bf16 cache, same inputs.
      capacity — requests in flight under ONE KV HBM budget, bf16 vs kv8
        pool (encoding.kv_capacity_requests with the layout's bytes/token);
        the CI gate holds the ratio >= 1.8.
      traffic — paged decode fused HBM bytes/token at 4k context, kv8 vs
        bf16 (per-page scales included); gated <= 0.6.

    Merges a "kv8" section into BENCH_decode.json, a "kv_quant" section into
    BENCH_paged.json, and returns CSV rows."""
    from repro.models import layers as L

    cfg = registry.get_reduced(arch)
    enc = EncodingConfig(enabled=True, backend="xla")
    params = T.model_init(jax.random.PRNGKey(0), cfg, enc)

    max_seq = 64
    block_size = 8
    rng = np.random.RandomState(0)
    n_req = 4 if quick else 8
    max_new = 6 if quick else 10
    prompts = [
        rng.randint(1, cfg.vocab_size, int(rng.randint(5, 13))).astype(np.int32)
        for _ in range(n_req)
    ]

    def serve(kv_quant):
        eng = engine_lib.Engine(
            params, cfg, enc,
            slots=3, max_seq=max_seq, cache_mode="paged",
            block_size=block_size, kv_quant=kv_quant,
        )
        for i, p in enumerate(prompts):
            eng.submit(engine_lib.Request(
                uid=i, prompt=p, max_new_tokens=max_new))
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        eng.audit()
        assert all(r.status == "ok" for r in eng.finished)
        toks = {r.uid: list(r.generated) for r in eng.finished}
        return toks, sum(len(g) for g in toks.values()) / dt, eng

    gold, bf16_tok_s, _ = serve("bf16")
    got, kv8_tok_s, eng8 = serve("kv8")
    assert eng8.stats["kv_quant"] == "kv8"

    # Teacher-force each gold continuation through the bf16 model: logits at
    # prompt_end-1 .. end-1 produced each generated token; their top-2
    # margins say where the decision was confident.
    conf_total = conf_match = 0
    all_identical = True
    for uid, g in sorted(gold.items()):
        seq = np.concatenate([prompts[uid], np.asarray(g, np.int32)])
        logits, _, _ = T.forward(
            params, {"tokens": jnp.asarray(seq[None, :])}, cfg=cfg, enc=enc,
            phase=Phase.PREFILL,
        )
        lg = logits[0, len(prompts[uid]) - 1: len(seq) - 1]  # one per gen tok
        top2 = jax.lax.top_k(lg, 2)[0]
        margin = np.asarray(top2[:, 0] - top2[:, 1])
        confident = margin >= np.median(margin)
        for i, (gt, kt) in enumerate(zip(g, got[uid])):
            if gt == kt:
                if confident[i]:
                    conf_total += 1
                    conf_match += 1
                continue
            # First divergence: a confident flip counts against the metric;
            # a near-tie flip is tolerated.  Either way the histories differ
            # from here on, so later positions are not comparable — stop.
            all_identical = False
            if confident[i]:
                conf_total += 1
            break
    token_identical_confident = (
        1.0 if conf_total and conf_match == conf_total else 0.0
    )

    # Codec relMSE on the decode-attention output (dequantized kv8 cache vs
    # the raw cache, identical queries/positions).
    layout = encoding.kv_layout("kv8")
    rng2 = np.random.RandomState(1)
    b, h, kv, d, s = 2, 4, 2, 16, 32
    k_raw = jnp.asarray(rng2.randn(b, s, kv, d), jnp.float32)
    v_raw = jnp.asarray(rng2.randn(b, s, kv, d), jnp.float32)
    q = jnp.asarray(rng2.randn(b, 1, h, d), jnp.float32)
    pos = jnp.asarray(rng2.randint(8, s, b), jnp.int32)
    o_fp = L.attention_decode(q, k_raw, v_raw, pos=pos, window=0)
    kq, ks = layout.quantize(k_raw)
    vq, vs = layout.quantize(v_raw)
    o_q = L.attention_decode(
        q, layout.dequantize(kq, ks), layout.dequantize(vq, vs),
        pos=pos, window=0,
    )
    rel_mse = float(jnp.sum(jnp.square(o_q - o_fp)) / jnp.sum(jnp.square(o_fp)))

    # Capacity under one HBM budget: the paged_cache_bench budget, repriced
    # per layout (scale pages included in bytes/token).
    itemsize = jnp.dtype(cfg.activation_dtype).itemsize
    hbm_budget = encoding.dense_kv_hbm_bytes(
        4, 128, cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
        itemsize=itemsize,
    )
    cap = {
        kvq: encoding.kv_capacity_requests(
            hbm_budget, max_seq=128, mean_tokens=24, block_size=block_size,
            num_layers=cfg.num_layers, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, itemsize=itemsize, kv_quant=kvq,
        )
        for kvq in ("bf16", "kv8", "kv4")
    }
    capacity_scaling = (
        cap["kv8"]["paged"] / max(cap["bf16"]["paged"], 1)
    )

    # Paged decode traffic at 4k context, full-size KV geometry (the same
    # geometry attention_bench prices): fused bytes/token kv8 vs bf16.
    kvh, hd, layers = 8, 64, 16
    traffic = {
        kvq: encoding.decode_attn_hbm_bytes(
            4096, max_seq=4096, block_size=16, num_kv_heads=kvh, head_dim=hd,
            num_layers=layers, itemsize=2, kv_quant=kvq,
        )
        for kvq in ("bf16", "kv8", "kv4")
    }
    bytes_ratio_4k = traffic["kv8"]["fused"] / traffic["bf16"]["fused"]

    kv8_stats = {
        "token_identical_confident": token_identical_confident,
        "token_identical_all_positions": 1.0 if all_identical else 0.0,
        "confident_positions": conf_total,
        "rel_mse_attn_out": rel_mse,
        "kv_capacity_scaling": capacity_scaling,
        "kv4_capacity_scaling": (
            cap["kv4"]["paged"] / max(cap["bf16"]["paged"], 1)
        ),
        "paged_bytes_ratio_vs_bf16_4k": bytes_ratio_4k,
        "kv4_bytes_ratio_vs_bf16_4k": (
            traffic["kv4"]["fused"] / traffic["bf16"]["fused"]
        ),
        "bytes_per_cached_token": {
            kvq: traffic[kvq]["bytes_per_cached_token"]
            for kvq in ("bf16", "kv8", "kv4")
        },
        "bf16_tok_s": bf16_tok_s,
        "kv8_tok_s": kv8_tok_s,
    }
    try:
        with open(out_json) as f:
            result = json.load(f)
    except (OSError, ValueError):
        result = {}
    result["kv8"] = kv8_stats
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    # Capacity detail rides with the paged-cache results.
    try:
        with open(out_paged_json) as f:
            presult = json.load(f)
    except (OSError, ValueError):
        presult = {}
    presult["kv_quant"] = {
        "hbm_budget_bytes": int(hbm_budget),
        "capacity_requests": {
            kvq: cap[kvq]["paged"] for kvq in ("bf16", "kv8", "kv4")
        },
        "kv8_capacity_scaling": capacity_scaling,
    }
    with open(out_paged_json, "w") as f:
        json.dump(presult, f, indent=2)
    return [
        ("kv8/token_identical_confident", token_identical_confident),
        ("kv8/rel_mse_attn_out", rel_mse),
        ("kv8/kv_capacity_scaling", capacity_scaling),
        ("kv8/paged_bytes_ratio_vs_bf16_4k", bytes_ratio_4k),
        ("kv8/tok_s", kv8_tok_s),
        ("kv8/bf16_tok_s", bf16_tok_s),
    ]


def main(*, quick: bool = False):
    if not quick:
        for name, val in model_throughput():
            print(f"{name},{val:.4f},cpu-wall-clock")
        for name, val in op_level_throughput():
            print(f"{name},{val:.4f},cpu-wall-clock")
    for name, val in decode_fastpath_bench(quick=quick):
        print(f"{name},{val:.4f},see-BENCH_decode.json")
    for name, val in attention_bench(quick=quick):
        print(f"{name},{val:.4f},see-BENCH_decode.json")
    for name, val in spec_decode_bench(quick=quick):
        print(f"{name},{val:.4f},see-BENCH_decode.json")
    for name, val in chaos_bench(quick=quick):
        print(f"{name},{val:.4f},see-BENCH_decode.json")
    for name, val in continuous_bench(quick=quick):
        print(f"{name},{val:.4f},see-BENCH_decode.json")
    for name, val in tp_bench(quick=quick):
        print(f"{name},{val:.4f},see-BENCH_decode.json")
    for name, val in paged_cache_bench(quick=quick):
        print(f"{name},{val:.4f},see-BENCH_paged.json")
    for name, val in prefix_cache_bench(quick=quick):
        print(f"{name},{val:.4f},see-BENCH_paged.json")
    for name, val in kv_quant_bench(quick=quick):
        print(f"{name},{val:.4f},see-BENCH_decode.json")


if __name__ == "__main__":
    main()
