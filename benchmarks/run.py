# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
#
#   table1_parity      — paper Table 1 (accuracy parity HF vs 10x-IREE,
#                        plus the Llama.cpp-style w8a8/w4a8 columns)
#   table2_throughput  — paper Table 2 (prefill/decode tokens/s per path)
#                        + the decode fast-path bench (BENCH_decode.json)
#   kernel_bench       — per-microkernel correctness + timing (Figs 1-2 analog)
#   roofline           — §Roofline terms from the dry-run (TPU projection),
#                        emitted when results/dryrun/ exists.
#
# ``--quick``: smoke mode — only the decode fast-path + paged-cache benches,
# tiny shapes and step counts, finishes in seconds (CI / local sanity).
#
# A failing bench section does not abort the others, but ANY failure makes the
# process exit nonzero — CI's bench-smoke job treats bench breakage as red
# (benchmarks/check_regression.py separately gates on the emitted numbers).

from __future__ import annotations

import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _run_sections(sections) -> int:
    failures = []
    for name, fn in sections:
        try:
            fn()
        except Exception as exc:  # propagate as nonzero exit, keep going
            traceback.print_exc()
            print(f"{name}/FAILED,0,{exc!r}")
            failures.append(name)
    if failures:
        print(f"run/FAILED_SECTIONS,{len(failures)},{';'.join(failures)}")
        return 1
    return 0


def main() -> int:
    from benchmarks import ablation_tiles, kernel_bench, table1_parity, table2_throughput

    print("name,us_per_call_or_value,derived")
    if "--quick" in sys.argv[1:]:
        return _run_sections([
            ("table2_quick", lambda: table2_throughput.main(quick=True)),
        ])

    sections = [
        ("table1", table1_parity.main),
        ("table2", table2_throughput.main),
        ("kernel_bench", kernel_bench.main),
        ("ablation_tiles", ablation_tiles.main),
    ]
    if os.path.isdir("results/dryrun") and os.listdir("results/dryrun"):
        from benchmarks import roofline

        sections.append(("roofline", roofline.main))
    else:
        print("roofline/SKIPPED,0,run repro.launch.dryrun first")
    return _run_sections(sections)


if __name__ == "__main__":
    sys.exit(main())
