# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
#
#   table1_parity      — paper Table 1 (accuracy parity HF vs 10x-IREE)
#   table2_throughput  — paper Table 2 (prefill/decode tokens/s per path)
#                        + the decode fast-path bench (BENCH_decode.json)
#   kernel_bench       — per-microkernel correctness + timing (Figs 1-2 analog)
#   roofline           — §Roofline terms from the dry-run (TPU projection),
#                        emitted when results/dryrun/ exists.
#
# ``--quick``: smoke mode — only the decode fast-path bench, tiny shapes and
# step counts, finishes in seconds (CI / local sanity).

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import ablation_tiles, kernel_bench, table1_parity, table2_throughput

    if "--quick" in sys.argv[1:]:
        print("name,us_per_call_or_value,derived")
        table2_throughput.main(quick=True)
        return

    print("name,us_per_call_or_value,derived")
    table1_parity.main()
    table2_throughput.main()
    kernel_bench.main()
    ablation_tiles.main()

    if os.path.isdir("results/dryrun") and os.listdir("results/dryrun"):
        from benchmarks import roofline

        roofline.main()
    else:
        print("roofline/SKIPPED,0,run repro.launch.dryrun first")


if __name__ == "__main__":
    main()
