"""Tile-size ablation — the paper's Methodology claim, re-validated for TPU.

Paper: "choosing a smaller tile size ... leads to underutilization of hardware
registers, while using bigger tile sizes increases register pressure that
causes register spills".  TPU analogue: the kernel-block selector must pick
the largest block that fits the VMEM budget; smaller blocks under-amortize
the accumulator (more K-revisits of HBM), larger ones exceed VMEM.

This ablation sweeps block shapes for a production-sized GEMM and reports,
per block: VMEM footprint, fits-budget, HBM traffic of the packed operands
under the kernel's reuse pattern (analytic: lhs read N1/bn1 times, rhs read
M1/bm1 times), and arithmetic intensity.  The selector's choice must be the
feasible point with maximal intensity.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import encoding, targets
from repro.core.encoding import Phase


def sweep(m=4096, n=8192, k=4096, itemsize=2):
    t = targets.TPU_V5E
    tiles = encoding.select_tile_sizes(Phase.PREFILL, lhs_dtype=jnp.bfloat16)
    m0, n0, k0 = tiles.as_tuple()
    m1, n1, k1 = m // m0, n // n0, k // k0
    rows = []
    for bm1 in (1, 2, 4, 8, 16):
        for bn1 in (1, 2, 4, 8, 16):
            for bk1 in (1, 2, 4, 8):
                if m1 % bm1 or n1 % bn1 or k1 % bk1:
                    continue
                lhs = bm1 * bk1 * m0 * k0 * itemsize
                rhs = bn1 * bk1 * n0 * k0 * itemsize
                acc = bm1 * bn1 * m0 * n0 * 4
                vmem = lhs + rhs + acc
                fits = vmem <= t.vmem_bytes * 0.5
                # HBM traffic: each lhs block is re-read once per N-block etc.
                traffic = (
                    m * k * itemsize * (n1 // bn1)
                    + n * k * itemsize * (m1 // bm1)
                    + m * n * 4
                )
                flops = 2.0 * m * n * k
                rows.append((bm1, bn1, bk1, vmem, fits, traffic, flops / traffic))
    return rows, (m0, n0, k0), (m1, n1, k1)


def main():
    rows, tiles, grid = sweep()
    sel = encoding.select_kernel_blocks(
        encoding.TileSizes(*tiles), Phase.PREFILL,
        m1=grid[0], n1=grid[1], k1=grid[2],
    )
    best_feasible = max((r for r in rows if r[4]), key=lambda r: r[6])
    print(f"ablation/tiles,{tiles},pack tile (MXU-native)")
    print(f"ablation/selected_blocks,({sel.bm1},{sel.bn1},{sel.bk1}),VMEM model")
    print(
        f"ablation/best_feasible_blocks,({best_feasible[0]},{best_feasible[1]},"
        f"{best_feasible[2]}),intensity={best_feasible[6]:.1f} flop/B"
    )
    for bm1, bn1, bk1, vmem, fits, traffic, inten in rows:
        tag = "fits" if fits else "SPILLS-VMEM"
        print(
            f"ablation/block_{bm1}x{bn1}x{bk1},{inten:.1f},"
            f"vmem={vmem/2**20:.2f}MiB;{tag};hbm={traffic/2**30:.2f}GiB"
        )
    # The paper's monotone claim, quantified: the selected block's intensity
    # must be within 10% of the best feasible point.
    sel_row = next(
        r for r in rows if (r[0], r[1], r[2]) == (sel.bm1, sel.bn1, sel.bk1)
    )
    ratio = sel_row[6] / best_feasible[6]
    print(f"ablation/selected_vs_best_intensity,{ratio:.3f},>=0.9 expected")
    return ratio


if __name__ == "__main__":
    main()
