"""Sharding rules: parameter-path -> PartitionSpec (FSDP + TP + EP + SP).

Scheme (DESIGN.md §5), on mesh axes (data, model) [+ pod]:
  * column-parallel packed weights (N1, K1, N0, K0): N1 -> model, K1 -> fsdp
  * row-parallel    packed weights              : N1 -> fsdp,  K1 -> model
  * embedding (V, D): vocab-parallel (V -> model)
  * KV caches: batch -> data, cache-seq -> model (decode sequence parallelism);
    recurrent states: heads/width -> model
  * small vectors (norms, biases, router, decay params): replicated
  * batch: leading dim over (pod,) data

Every spec is *sanitized* against the concrete shape: a mesh axis that does
not divide its dimension is dropped (e.g. batch=1 in long_500k stays
replicated instead of failing to lower).  Desired-vs-effective sharding is
thereby decoupled from the shape grid.
"""

from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Path-name classification for packed (or plain transposed) weights.
_COLUMN_NAMES = {
    "wq", "wk", "wv", "w_gate", "w_up", "cm_wk", "w_in", "w_gate_branch",
    "wr", "wg", "w_a", "w_x", "fc1", "fc2", "head",
}
_ROW_NAMES = {"wo", "w_down", "cm_wv", "w_out"}
_REPLICATED_NAMES = {"router"}


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def sanitize(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop axes that don't divide their dimension."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axes in zip(shape, parts):
        if axes is None:
            out.append(None)
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        kept: list[str] = []
        for a in tup:
            size = _axis_size(mesh, tuple(kept + [a]))
            if dim % size == 0:
                kept.append(a)
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def _fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("data", "pod") if a in mesh.axis_names)


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _path_names(path) -> list[str]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(f"[{p.idx}]")
        elif hasattr(p, "name"):
            names.append(str(p.name))
    return names


def param_spec(path, leaf, mesh: Mesh, *, fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf, from its tree path."""
    names = _path_names(path)
    leafname = names[-1] if names else ""
    owner = names[-2] if len(names) >= 2 else ""
    fa = _fsdp_axes(mesh) if fsdp else ()
    nd = leaf.ndim

    def packed_spec(n1_axes, k1_axes):
        # (..., N1, K1, N0, K0): leading dims (layer-stack, experts) unsharded.
        lead = [None] * (nd - 4)
        return P(*lead, n1_axes, k1_axes, None, None)

    if leafname == "w_scale" and nd >= 2:  # int8 per-channel scales (..., N1, N0)
        is_col = owner in _COLUMN_NAMES
        lead = [None] * (nd - 2)
        return P(*lead, "model", None) if is_col else P(*lead, fa or None, None)
    if leafname in ("w_packed", "w_q") or (leafname == "w_t" and nd >= 2):
        if owner in _REPLICATED_NAMES:
            return P(*([None] * nd))
        is_col = owner in _COLUMN_NAMES
        if leafname == "w_t":
            lead = [None] * (nd - 2)
            return P(*lead, "model", fa or None) if is_col else P(*lead, fa or None, "model")
        return packed_spec("model", fa or None) if is_col else packed_spec(fa or None, "model")
    if leafname == "embed":
        return P("model", None)
    if leafname == "dec_pos_embed":
        return P(None, None)
    if leafname == "b" and owner in _COLUMN_NAMES and nd == 1:
        return P("model")
    # Norms, biases, conv weights, decay params, mus, loras: replicated.
    return P(*([None] * nd))


def params_shardings(params, mesh: Mesh, *, fsdp: bool = True):
    def one(path, leaf):
        spec = param_spec(path, leaf, mesh, fsdp=fsdp)
        return NamedSharding(mesh, sanitize(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, params)


def batch_shardings(batch, mesh: Mesh):
    dp = _dp_axes(mesh)

    def one(leaf):
        spec = P(dp, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, sanitize(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map(one, batch)


def cache_shardings(caches, mesh: Mesh):
    """KV caches (G?, B, S, KV, hd): batch->data, seq->model (SP decode).
    Recurrent states (G?, B, ...): batch->data, first state dim -> model."""
    dp = _dp_axes(mesh)

    def one(path, leaf):
        names = _path_names(path)
        leafname = names[-1] if names else ""
        nd = leaf.ndim
        if leafname in ("k", "v", "cross_k", "cross_v") and nd >= 4:
            lead = [None] * (nd - 4)
            spec = P(*lead, dp, "model", None, None)
        elif leafname == "S" and nd >= 4:  # rwkv state (..., B, H, dk, dv)
            lead = [None] * (nd - 4)
            spec = P(*lead, dp, "model", None, None)
        elif leafname == "h" and nd >= 2:  # rg-lru state (..., B, rw)
            lead = [None] * (nd - 2)
            spec = P(*lead, dp, "model")
        elif leafname == "conv" and nd >= 3:
            lead = [None] * (nd - 3)
            spec = P(*lead, dp, None, "model")
        elif leafname in ("shift_tm", "shift_cm") and nd >= 2:
            lead = [None] * (nd - 2)
            spec = P(*lead, dp, "model")
        else:
            spec = P(*([None] * nd))
        return NamedSharding(mesh, sanitize(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, caches)


def serving_cache_shardings(caches, mesh: Mesh):
    """Serving (tensor-parallel) KV-cache shardings: KV HEADS -> model.

    The training-time `cache_shardings` shards the sequence axis (decode
    SP); the serving engine instead runs head-parallel attention — each
    shard owns the K/V slice of its own kv-head group, matching the
    column-parallel wk/wv projections, so attention needs NO collective
    until the row-parallel wo matmul's psum.  Covers both cache layouts:

      dense  k/v: (G?, B,         S,     KV, hd)  -> heads (axis -2) on model
      paged  k/v: (G?, num_pages, block, KV, hd)  -> heads (axis -2) on model
      table     : (..., nb) block tables          -> replicated (host-mirrored)

    `sanitize` drops the axis when kv_heads doesn't divide the shard count
    (e.g. the reduced test configs' kv=1 under tp=2) — the cache replicates
    and GSPMD still produces identical tokens, just without the capacity
    win (docs/PERF.md §Tensor-parallel capacity).

    Prefix-cache interaction: the radix tree, refcounts, tenant ledgers and
    LRU clock are HOST-side metadata, mirrored per shard by
    `ShardedBlockAllocator` (serving/paged.py) — nothing of the tree lives
    on device.  Because every shard runs the identical, deterministic
    plan/commit/evict sequence, page number N means "prefix block X" on
    every shard simultaneously, and a cache hit revives the full kv-head
    slice set of that page with no collective: each shard's pool rows for
    page N already hold that shard's head slice, sharded by the rule
    above."""

    def one(path, leaf):
        names = _path_names(path)
        leafname = names[-1] if names else ""
        nd = leaf.ndim
        # Quantized-layout scale pages (G?, num_pages, block, KV, 1) carry
        # their kv-head axis at -2 exactly like the data pages they scale;
        # they must shard alongside them or a shard would dequantize its
        # head slice with another shard's magnitudes.
        if leafname in (
            "k", "v", "cross_k", "cross_v", "k_scale", "v_scale"
        ) and nd >= 4:
            spec = P(*([None] * (nd - 2)), "model", None)
        else:
            spec = P(*([None] * nd))
        return NamedSharding(mesh, sanitize(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, caches)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def spec_tree_for(tree, mesh: Mesh, kind: str):
    if kind == "params":
        return params_shardings(tree, mesh)
    if kind == "batch":
        return batch_shardings(tree, mesh)
    if kind == "caches":
        return cache_shardings(tree, mesh)
    raise ValueError(kind)
