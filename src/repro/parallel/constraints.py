"""Ambient-mesh sharding constraints.

`shard(x, *axes)` applies `with_sharding_constraint` against whatever mesh is
ambient (jax.set_mesh), sanitizing the spec first: axes not present in the
mesh, or not dividing their dimension, are dropped.  Outside any mesh context
it is a no-op, so model code can sprinkle constraints freely and still run in
plain CPU tests.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _ambient_axis_sizes() -> dict[str, int]:
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - very old jax
        return {}
    if am is None or getattr(am, "empty", True):
        return {}
    return dict(am.shape)


def shard(x, *axes):
    """axes: one entry per leading dim (None | str | tuple); trailing dims None."""
    sizes = _ambient_axis_sizes()
    if not sizes:
        return x
    spec = []
    for dim, a in zip(x.shape, list(axes) + [None] * (x.ndim - len(axes))):
        if a is None:
            spec.append(None)
            continue
        tup = (a,) if isinstance(a, str) else tuple(a)
        kept, prod = [], 1
        for name in tup:
            if name not in sizes:
                continue
            if dim % (prod * sizes[name]) == 0:
                kept.append(name)
                prod *= sizes[name]
        spec.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return jax.lax.with_sharding_constraint(x, P(*spec))
