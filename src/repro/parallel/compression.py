"""int8 gradient compression with error feedback.

Distributed-optimization trick for the DP all-reduce: gradients are quantized
to int8 (per-leaf symmetric scale) before the data-parallel reduction;
quantization error is carried in an error-feedback buffer and added back the
next step, so the compressed SGD trajectory provably tracks the exact one
(Karimireddy et al., 2019).  Under jit+SPMD the quantized representation is
what crosses the ICI during gradient reduction, cutting collective bytes 4x
(f32) / 2x (bf16) — accounted in the §Roofline collective term.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_state(params):
    return {"error": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def _quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, state):
    """Error-feedback int8 round trip. Returns (decompressed_grads, new_state)."""

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree.leaves(state["error"])
    new_g, new_e = [], []
    for g, e in zip(flat_g, flat_e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _quantize(corrected)
        deq = _dequantize(q, scale)
        new_g.append(deq)
        new_e.append(corrected - deq)
    unflatten = jax.tree_util.tree_unflatten
    return unflatten(treedef, new_g), {"error": unflatten(treedef, new_e)}
