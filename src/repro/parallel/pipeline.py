"""Pipeline parallelism over the `pod` axis (GPipe schedule).

Stages map 1:1 to pods; stage s holds the s-th slice of the layer stack
(params sharded over `pod` on their leading dim).  The schedule runs
M + S - 1 ticks: each tick every stage computes its resident microbatch and
`ppermute`s activations to the next stage (shard_map makes the transfer an
explicit neighbour ICI hop — the multi-pod link, which is the point of PP:
activations cross the pod boundary once per microbatch instead of weights /
gradients every layer).

Static-shape trick: idle ticks compute garbage that is masked out of the
output accumulator — standard for SPMD pipelines (bubbles are real, compute
is constant per tick).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe_forward(stage_fn, stage_params, xs, *, mesh, axis: str = "pod"):
    """Run `stage_fn(params_slice, x) -> y` through S pipeline stages.

    stage_params: pytree, every leaf (S, ...) — stage dim sharded over `axis`.
    xs: (M, ...) microbatch stack (replicated over `axis`).
    Returns (M, ...) outputs of the final stage.
    """
    s_stages = mesh.shape[axis]
    m = xs.shape[0]
    ticks = m + s_stages - 1

    def local(params_s, xs_local):
        # params_s leaves: (1, ...); xs_local: (M, ...) [replicated copy]
        idx = jax.lax.axis_index(axis)
        p0 = jax.tree.map(lambda a: a[0], params_s)

        def tick(carry, t):
            acc, cur_in = carry
            mb = jnp.clip(t, 0, m - 1)
            inp = jnp.where(idx == 0, xs_local[mb], cur_in)
            out = stage_fn(p0, inp)
            # Shift activations one stage forward (ring permute; the wrap
            # link is unused — its payload is masked at stage 0 next tick).
            perm = [(i, (i + 1) % s_stages) for i in range(s_stages)]
            nxt = jax.lax.ppermute(out, axis, perm)
            slot = jnp.clip(t - (s_stages - 1), 0, m - 1)
            take = t >= (s_stages - 1)
            acc = acc.at[slot].set(jnp.where(take, out, acc[slot]))
            return (acc, nxt), None

        acc0 = jnp.zeros((m,) + xs_local.shape[1:], xs_local.dtype)
        cur0 = jnp.zeros_like(xs_local[0])
        # The carry becomes device-varying (depends on axis_index / ppermute):
        # mark the initial value accordingly for shard_map's vma typing.
        acc0 = jax.lax.pcast(acc0, (axis,), to="varying")
        cur0 = jax.lax.pcast(cur0, (axis,), to="varying")
        (acc, _), _ = jax.lax.scan(tick, (acc0, cur0), jnp.arange(ticks))
        return acc[None]  # (1, M, ...) per stage

    in_specs = (P(axis), P(*([None] * xs.ndim)))
    out = jax.shard_map(
        local, mesh=mesh, in_specs=in_specs, out_specs=P(axis)
    )(stage_params, xs)
    # out: (S, M, ...); only the final stage's block carries the result.
    return out[-1]


def stack_stages(layer_params, num_stages: int):
    """Re-stack a (L, ...) layer pytree into (S, L/S, ...) stage slices."""

    def one(leaf):
        l = leaf.shape[0]
        assert l % num_stages == 0, (l, num_stages)
        return leaf.reshape(num_stages, l // num_stages, *leaf.shape[1:])

    return jax.tree.map(one, layer_params)
