"""Fault-tolerant checkpointing.

  * atomic: leaves written into a tmp dir; manifest (shapes/dtypes/sha256)
    last; directory renamed into place — a crash mid-save never corrupts the
    latest checkpoint.
  * async: `save_async` snapshots to host memory synchronously (cheap) and
    writes in a daemon thread, overlapping I/O with the next train steps.
  * resharding restore: leaves are stored unsharded; `restore` device_puts
    onto any target sharding tree — save on 512 chips, restore on 256 (or on
    the elastic mesh after a failure).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(state) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def _leaf_file(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save(ckpt_dir: str, state, step: int) -> str:
    """Atomic synchronous save. Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(_flatten(state)):
        arr = np.asarray(jax.device_get(leaf))
        fname = _leaf_file(i)
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, arr)
        with open(os.path.join(tmp, fname), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype), "sha256": digest}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot-to-host sync, write-to-disk async; at most one in flight."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: threading.Thread | None = None

    def save(self, state, step: int):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, host_state, step), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, *, shardings=None, verify: bool = True):
    """Restore into the structure of `like` (values ignored), optionally
    device_put onto `shardings` (same treedef) — this is the reshard path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like, treedef = jax.tree_util.tree_flatten(like)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    keys = [jax.tree_util.keystr(p) for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    assert len(keys) == len(flat_like)

    sh_flat = jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(keys)

    leaves = []
    for key, ref, sh in zip(keys, flat_like, sh_flat):
        entry = by_key[key]
        fpath = os.path.join(path, entry["file"])
        if verify:
            with open(fpath, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != entry["sha256"]:
                raise IOError(f"checksum mismatch for {key} in {path}")
        arr = np.load(fpath)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
