"""Device-encoding materialization — the paper's compiler pass, as a JAX library.

IREE's `iree-codegen-materialize-device-encoding` pass rewrites contraction ops
into `tensor.pack -> linalg.mmt4d -> tensor.unpack` with target/phase-aware tile
sizes.  Here the same decision is made by `select_tile_sizes`, and the rewrite
is performed by `encode_matmul` / `PackedLinear` (core/packed.py): every dense
projection in the model zoo routes through this module.

Layouts (paper semantics, identical on TPU):
    pack(lhs, (M0, K0)) : (M, K)            -> (M1, K1, M0, K0)
    pack(rhs, (N0, K0)) : (N, K)  [= W^T]   -> (N1, K1, N0, K0)   # the 't' in mmt4d
    mmt4d(lhs4, rhs4)   :                   -> (M1, N1, M0, N0), f32 accumulate
    unpack(out4, (M,N)) : (M1, N1, M0, N0)  -> (M, N)

Two tiling levels (TPU adaptation):
  * the *pack tile* (M0, N0, K0) — the layout granularity, matched to the
    compute unit (MXU 128x128 for GEMM; VREG sublane x lane for GEMV).  This is
    the analogue of the paper's register tile.
  * the *kernel block* (BM1, BN1, BK1) — how many pack tiles one Pallas grid
    step keeps resident in VMEM.  The paper's ceiling is register spills; ours
    is the VMEM budget, encoded in `select_kernel_blocks`.
"""

from __future__ import annotations

import dataclasses
import enum
import math

import jax.numpy as jnp

from repro.core import targets as targets_lib


class Phase(enum.Enum):
    """Execution phase.  Matmul shape regime differs per phase (paper §Methodology)."""

    PREFILL = "prefill"   # GEMM: M = batch*seq rows
    DECODE = "decode"     # GEMV-class: M = batch rows (1 token each)
    TRAIN = "train"       # GEMM, fwd+bwd


@dataclasses.dataclass(frozen=True)
class TileSizes:
    m0: int
    n0: int
    k0: int

    def as_tuple(self) -> tuple[int, int, int]:
        return (self.m0, self.n0, self.k0)


@dataclasses.dataclass(frozen=True)
class KernelBlocks:
    """Pack-tile multiples held in VMEM per grid step."""

    bm1: int
    bn1: int
    bk1: int


def paper_tile_sizes(phase: Phase, vlen_bits: int = targets_lib.RISCV_VLEN_BITS) -> TileSizes:
    """The paper's published RVV rule (Methodology step 1):

        prefill: M,N,K = 6, VLEN/8, 1
        decode : M,N,K = 1, VLEN/4, 1
    """
    if phase in (Phase.PREFILL, Phase.TRAIN):
        return TileSizes(6, vlen_bits // 8, 1)
    return TileSizes(1, vlen_bits // 4, 1)


def select_tile_sizes(
    phase: Phase,
    *,
    lhs_dtype=jnp.bfloat16,
    m_hint: int | None = None,
    target: targets_lib.TargetSpec = targets_lib.TPU_V5E,
) -> TileSizes:
    """Target/phase-aware pack-tile selection (the VLEN-aware rule, re-solved for TPU).

    GEMM phases want MXU-native 128-multiples.  DECODE is bandwidth-bound: the
    M tile collapses to the (few) live batch rows, and N widens so the kernel
    streams weights with full lanes — the direct analogue of the paper widening
    N to VLEN/4 for GEMV.
    """
    if target.mxu_dim == 1:
        # Vector-only target: reproduce the paper's rule exactly.
        return paper_tile_sizes(phase)

    itemsize = jnp.dtype(lhs_dtype).itemsize
    sub = targets_lib.sublanes_for_dtype(target, itemsize)
    if phase in (Phase.PREFILL, Phase.TRAIN):
        return TileSizes(m0=target.mxu_dim, n0=target.mxu_dim, k0=target.mxu_dim)
    # DECODE: m0 covers the live rows, capped at one sublane group.
    rows = m_hint if m_hint is not None else 1
    m0 = max(1, min(sub, rows))
    return TileSizes(m0=m0, n0=4 * target.lane_count, k0=target.mxu_dim)


def select_kernel_blocks(
    tiles: TileSizes,
    phase: Phase,
    *,
    m1: int,
    n1: int,
    k1: int,
    lhs_itemsize: int = 2,
    rhs_itemsize: int = 2,
    acc_itemsize: int = 4,
    target: targets_lib.TargetSpec = targets_lib.TPU_V5E,
    vmem_fraction: float = 0.5,
) -> KernelBlocks:
    """VMEM-budgeted block selection — replaces the paper's register-spill ceiling.

    Per grid step the kernel holds:
        lhs block  BM1*BK1*M0*K0*lhs_itemsize
        rhs block  BN1*BK1*N0*K0*rhs_itemsize
        acc scratch BM1*BN1*M0*N0*acc_itemsize
    and the total must fit `vmem_fraction * target.vmem_bytes` (double-buffering
    headroom for the pipelined HBM->VMEM copies takes the rest).
    """
    budget = target.vmem_bytes * vmem_fraction
    m0, n0, k0 = tiles.as_tuple()

    def footprint(bm1: int, bn1: int, bk1: int) -> float:
        lhs = bm1 * bk1 * m0 * k0 * lhs_itemsize
        rhs = bn1 * bk1 * n0 * k0 * rhs_itemsize
        acc = bm1 * bn1 * m0 * n0 * acc_itemsize
        return lhs + rhs + acc

    bm1, bn1, bk1 = 1, 1, 1
    # Greedy doubling, largest-marginal-benefit first: K depth amortizes the
    # accumulator, then N (weight reuse), then M (activation reuse).
    order = ("bk1", "bn1", "bm1") if phase is not Phase.DECODE else ("bn1", "bk1", "bm1")
    grew = True
    while grew:
        grew = False
        for name in order:
            cand = dict(bm1=bm1, bn1=bn1, bk1=bk1)
            lim = dict(bm1=m1, bn1=n1, bk1=k1)
            if cand[name] * 2 > lim[name]:
                continue
            cand[name] *= 2
            if footprint(**cand) <= budget:
                bm1, bn1, bk1 = cand["bm1"], cand["bn1"], cand["bk1"]
                grew = True
    return KernelBlocks(bm1=bm1, bn1=bn1, bk1=bk1)


def decode_projection_hbm_bytes(
    m: int,
    n: int,
    k: int,
    *,
    act_itemsize: int = 2,
    weight_itemsize: int = 2,
    out_itemsize: int = 4,
) -> dict[str, int]:
    """HBM traffic model for ONE decode projection (m live rows, W (n, k)).

    Both paths stream the packed weight once (n*k bytes — the decode roofline
    term) and read/write the plain activation row and output.  The unfused
    path additionally materializes the packed activation and packed output in
    HBM, paying a write+read round-trip for each; the fused GEMV keeps both
    relayouts inside the kernel (see kernels/fused_gemv.py and docs/PERF.md).
    """
    base = n * k * weight_itemsize + m * k * act_itemsize + m * n * out_itemsize
    pack_rt = 2 * m * k * act_itemsize      # packed-lhs write + read back
    unpack_rt = 2 * m * n * out_itemsize    # packed-out write + read back
    return {
        "unfused": base + pack_rt + unpack_rt,
        "fused": base,
        "saved": pack_rt + unpack_rt,
    }


def quant_weight_stream_bytes(
    n: int,
    k: int,
    *,
    quant: str = "none",
    weight_itemsize: int = 2,
    group: int = 16,
    scale_itemsize: int = 2,
) -> int:
    """Bytes one decode step streams for a W (n, k) projection, per quant mode.

    This is THE decode roofline term (the weight is read once per token):
      none : n*k*weight_itemsize                        (bf16: 2 bytes/weight)
      w8a8 : n*k + n*4                                  (int8 + per-channel f32)
      w4a8 : n*k/2 + n*ceil(k/group)*scale_itemsize     (nibbles + group scales)
    With bf16 scales and g=16, w4a8 streams 0.625 bytes/weight — 1.6x less
    than w8a8 and 3.2x less than bf16; the model-projected decode tokens/s
    scale inversely (see decode_weight_stream_tok_s and docs/PERF.md)."""
    if quant in ("none",):
        return n * k * weight_itemsize
    if quant in ("w8a8", "int8"):
        return n * k + n * 4
    if quant in ("w4a8", "int4"):
        return n * (k // 2) + n * math.ceil(k / group) * scale_itemsize
    raise ValueError(f"unknown quant mode {quant!r}")


def decode_weight_stream_tok_s(
    weight_bytes: int, target: targets_lib.TargetSpec = targets_lib.TPU_V5E
) -> float:
    """Upper-bound decode tokens/s from the weight-streaming roofline: every
    generated token re-reads `weight_bytes` from HBM; nothing else scales
    with the token count in the bandwidth-bound regime."""
    return target.hbm_bytes_per_s / max(1, weight_bytes)


# ---------------------------------------------------------------------------
# Quantized KV-cache layouts (kv8 / kv4).
#
# The same fuse-dequant-into-the-contraction move the mmt4d_q4 weight path
# proves out, applied to the OTHER decode HBM stream: K/V pages are stored
# int8 (kv8) or packed int4 nibbles (kv4) with a float32 per-token-per-head
# scale living in parallel *scale pages* (same page geometry as the data
# pages, so the BlockAllocator's page ids index both).  The attention kernels
# ride the scale pages as extra BlockSpec operands and dequantize tile-locally
# in VMEM before the online-softmax accumulate (kernels/attn.py).

KV_QUANTS = ("bf16", "kv8", "kv4")
KV_SCALE_ITEMSIZE = 4  # float32 scale per (token, kv-head)


def _unpack_nibbles(packed):
    """(…, hd//2) packed uint8 -> (…, hd) int32 in [-8, 7].

    Even head_dim elements live in the low nibble, odd in the high nibble
    (two's complement).  Pure jnp, safe inside Pallas kernel bodies."""
    b = packed.astype(jnp.int32)
    lo = b & 0xF
    hi = (b >> 4) & 0xF
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    return jnp.stack([lo, hi], axis=-1).reshape(*b.shape[:-1], b.shape[-1] * 2)


def _pack_nibbles(q):
    """(…, hd) int32 in [-8, 7] -> (…, hd//2) uint8 (inverse of _unpack_nibbles)."""
    lo = q[..., 0::2] & 0xF
    hi = q[..., 1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.uint8)


@dataclasses.dataclass(frozen=True)
class KVLayout:
    """One KV-cache storage layout: dtype, scale shape, codec, byte accounting.

    Every layer that touches K/V arrays goes through this object instead of
    assuming raw bf16: cache init sizes the leaves (`storage_head_dim`,
    `scale_shape`), the scatter-write paths quantize per page (`quantize`),
    the attention kernels / XLA fallback dequantize (`dequantize`), and the
    capacity math prices a cached token (`bytes_per_token_per_head`).

    Scales are per (token, kv-head) — decode scatters single tokens into
    pages with `.at[page, offset].set`, so a per-page *scalar* would
    retroactively re-scale previously written tokens; per-token scales kept
    in page-shaped scale arrays give page-granular alloc/free/COW with
    write-once token semantics.
    """

    name: str
    storage_dtype: object | None  # None = keep the model activation dtype
    pack_ratio: int               # head_dim elements per storage element
    qmax: int                     # symmetric integer clip bound (0 = unquantized)

    @property
    def quantized(self) -> bool:
        return self.qmax > 0

    def storage_head_dim(self, head_dim: int) -> int:
        if self.pack_ratio > 1 and head_dim % self.pack_ratio:
            raise ValueError(
                f"{self.name}: head_dim {head_dim} not divisible by pack "
                f"ratio {self.pack_ratio}"
            )
        return head_dim // self.pack_ratio

    def scale_shape(self, lead: tuple[int, ...], num_kv_heads: int) -> tuple[int, ...]:
        """Shape of the scale leaf matching data-leaf leading dims `lead`
        (e.g. (num_pages, block) or (batch, seq)) — heads stay at axis -2
        so the TP sharding rule for K/V applies unchanged."""
        return (*lead, num_kv_heads, 1)

    def bytes_per_token_per_head(self, head_dim: int) -> float:
        if not self.quantized:
            return float(head_dim * 2)  # bf16 storage, no scales
        return float(
            self.storage_head_dim(head_dim) * jnp.dtype(self.storage_dtype).itemsize
            + KV_SCALE_ITEMSIZE
        )

    def quantize(self, x):
        """(…, hd) float -> (q (…, hd / pack_ratio) storage_dtype,
        scale (…, 1) float32).  Symmetric absmax per (token, head) row."""
        assert self.quantized, f"{self.name} has no codec"
        xf = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / self.qmax
        q = jnp.clip(jnp.round(xf / scale), -self.qmax, self.qmax).astype(jnp.int32)
        if self.pack_ratio > 1:
            return _pack_nibbles(q), scale
        return q.astype(self.storage_dtype), scale

    def dequantize(self, q, scale):
        """Inverse of `quantize` -> float32.  Pure jnp — the attention
        kernels call this on VMEM-resident tiles."""
        assert self.quantized, f"{self.name} has no codec"
        vals = _unpack_nibbles(q) if self.pack_ratio > 1 else q.astype(jnp.int32)
        return vals.astype(jnp.float32) * scale


_KV_LAYOUTS = {
    "bf16": KVLayout(name="bf16", storage_dtype=None, pack_ratio=1, qmax=0),
    "kv8": KVLayout(name="kv8", storage_dtype=jnp.int8, pack_ratio=1, qmax=127),
    "kv4": KVLayout(name="kv4", storage_dtype=jnp.uint8, pack_ratio=2, qmax=7),
}


def kv_layout(name: str) -> KVLayout:
    try:
        return _KV_LAYOUTS[name]
    except KeyError:
        raise ValueError(f"unknown kv_quant {name!r}; expected one of {KV_QUANTS}")


def kv_layout_for_storage(dtype) -> KVLayout:
    """Recover the layout from a cache leaf's dtype — caches are
    self-describing, so jitted model code never needs the config threaded
    through (int8 pools = kv8, packed uint8 = kv4, floats = bf16)."""
    dt = jnp.dtype(dtype)
    if dt == jnp.dtype(jnp.int8):
        return _KV_LAYOUTS["kv8"]
    if dt == jnp.dtype(jnp.uint8):
        return _KV_LAYOUTS["kv4"]
    return _KV_LAYOUTS["bf16"]


def kv_bytes_per_token(
    num_layers: int, num_kv_heads: int, head_dim: int, *, itemsize: int = 2,
    kv_quant: str = "bf16",
) -> int:
    """HBM bytes one cached token costs across all layers (K and V).

    For quantized layouts the per-head cost comes from the KVLayout codec
    (storage bytes + the float32 scale); `itemsize` only prices bf16."""
    if kv_quant in (None, "bf16"):
        return 2 * num_layers * num_kv_heads * head_dim * itemsize
    per_head = kv_layout(kv_quant).bytes_per_token_per_head(head_dim)
    return int(2 * num_layers * num_kv_heads * per_head)


def decode_attn_hbm_bytes(
    context: int,
    *,
    max_seq: int | None = None,
    block_size: int = 16,
    num_kv_heads: int,
    head_dim: int,
    num_layers: int = 1,
    itemsize: int = 2,
    kv_quant: str = "bf16",
) -> dict[str, float]:
    """Decode-attention HBM traffic model for ONE generated token of ONE
    sequence at `context` cached tokens (all layers, K and V).

    gather (the pre-kernel fallback): `paged_gather` materializes the full
    logical view over the table width (ceil(max_seq / block) blocks) — the
    pool pages are READ, the dense view is WRITTEN, and the attention
    softmax READS it back: 3 passes over the table-width KV footprint,
    independent of how much of it is live.

    bounded_gather: the same fallback after the table is narrowed to the
    slot's allocated page count (engine._with_tables / paged_gather
    nb_blocks) — still 3 passes, but only over live blocks.

    fused: the paged-decode kernel (kernels/attn.py) streams each live page
    HBM->VMEM exactly once and materializes nothing: 1 pass over live
    blocks.  This is the O(pool) -> O(live) conversion the attention op
    class buys; `ratio` = fused / gather is the CI-gated headline
    (<= 0.5 at 4k context — benchmarks/check_regression.py).

    `kv_quant` prices the stream per KVLayout: kv8/kv4 shrink every row
    (the kernel streams the int pages plus their scale pages instead of
    bf16) — the second CI-gated headline is fused(kv8)/fused(bf16) <= 0.6
    at 4k context (docs/PERF.md §Decode-attention traffic).
    """
    max_seq = max_seq or context
    per_tok = kv_bytes_per_token(
        num_layers, num_kv_heads, head_dim, itemsize=itemsize, kv_quant=kv_quant
    )
    view = -(-max_seq // block_size) * block_size
    live = max(1, -(-context // block_size)) * block_size
    gather = 3 * view * per_tok
    fused = live * per_tok
    return {
        "gather": float(gather),
        "bounded_gather": float(3 * live * per_tok),
        "fused": float(fused),
        "ratio": fused / gather,
        "bytes_per_cached_token": float(per_tok),
        "kv_quant": kv_quant,
    }


def attn_weight_crossover_tokens(
    weight_stream_bytes: int,
    *,
    num_kv_heads: int,
    head_dim: int,
    num_layers: int,
    itemsize: int = 2,
    kv_quant: str = "bf16",
) -> float:
    """Context length where fused decode-attention traffic equals the
    per-token weight stream: past this many cached tokens, KV traffic — not
    the weight stream — is the decode roofline, which is why attention was
    the mandatory next microkernel after the w4a8 weight path (docs/PERF.md
    §Decode-attention traffic).  Quantized KV pushes the crossover out by
    the bytes/token ratio (kv8 ~1.9x, kv4 ~3.6x at hd=64)."""
    per_tok = kv_bytes_per_token(
        num_layers, num_kv_heads, head_dim, itemsize=itemsize, kv_quant=kv_quant
    )
    return weight_stream_bytes / max(1, per_tok)


def dense_kv_hbm_bytes(
    slots: int, max_seq: int, num_layers: int, num_kv_heads: int, head_dim: int,
    *, itemsize: int = 2, kv_quant: str = "bf16",
) -> int:
    """Dense serving reservation: every slot pays worst-case max_seq tokens."""
    return slots * max_seq * kv_bytes_per_token(
        num_layers, num_kv_heads, head_dim, itemsize=itemsize, kv_quant=kv_quant
    )


def paged_kv_hbm_bytes(
    num_pages: int, block_size: int, num_layers: int, num_kv_heads: int,
    head_dim: int, *, itemsize: int = 2, kv_quant: str = "bf16",
) -> int:
    """Paged pool footprint (scratch page included): pages x block tokens.
    Quantized layouts count the scale pages too (KVLayout accounting)."""
    return num_pages * block_size * kv_bytes_per_token(
        num_layers, num_kv_heads, head_dim, itemsize=itemsize, kv_quant=kv_quant
    )


def kv_capacity_requests(
    hbm_budget: int,
    *,
    max_seq: int,
    mean_tokens: int,
    block_size: int,
    num_layers: int,
    num_kv_heads: int,
    head_dim: int,
    itemsize: int = 2,
    kv_quant: str = "bf16",
) -> dict[str, int]:
    """Concurrent requests one KV HBM budget sustains, dense vs paged.

    Dense reserves max_seq tokens per slot regardless of use; paged holds
    ceil(mean_tokens / block_size) pages per in-flight request (mean_tokens =
    typical prompt + generated length), so the capacity ratio is roughly
    max_seq / round_up(mean_tokens, block_size) — the serving-plan headroom
    the paged engine converts into admitted requests (docs/PERF.md).
    `kv_quant` shrinks bytes_per_token via the KVLayout, multiplying the
    pool a fixed budget sustains (the kv8 bench gate pins >= 1.8x bf16)."""
    ptb = kv_bytes_per_token(
        num_layers, num_kv_heads, head_dim, itemsize=itemsize, kv_quant=kv_quant
    )
    dense = hbm_budget // max(1, max_seq * ptb)
    blocks_per_req = max(1, -(-mean_tokens // block_size))
    paged = hbm_budget // max(1, blocks_per_req * block_size * ptb)
    return {
        "dense": int(dense),
        "paged": int(paged),
        "bytes_per_token": ptb,
        "blocks_per_request": blocks_per_req,
    }


def tp_kv_capacity_requests(
    hbm_budget_per_shard: int,
    *,
    shards: int,
    max_seq: int,
    mean_tokens: int,
    block_size: int,
    num_layers: int,
    num_kv_heads: int,
    head_dim: int,
    itemsize: int = 2,
    kv_quant: str = "bf16",
) -> dict[str, float]:
    """`kv_capacity_requests` under head-parallel tensor parallelism
    (docs/PERF.md §Tensor-parallel capacity math).

    Each of `shards` devices holds the SAME per-shard HBM budget but only
    its own kv-head slice of every page (num_kv_heads / shards heads), so a
    token's per-shard KV footprint shrinks by the shard count and the pool
    a fixed per-device budget sustains grows by it: capacity scales with
    SHARDS, not just pool pages.  When the heads do NOT divide, the
    sharding sanitizer replicates the KV cache instead (correctness
    preserved, capacity win forfeited) — reported honestly as scaling 1.0.

    Returns the dense/paged request capacities at this shard count plus
    `scaling_vs_1` (paged capacity relative to the same budget at shards=1
    — exactly `shards` for dividing heads; the bench gate pins >= 1.8 at
    2 shards)."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    divides = num_kv_heads % shards == 0
    local_heads = num_kv_heads // shards if divides else num_kv_heads
    base = kv_capacity_requests(
        hbm_budget_per_shard, max_seq=max_seq, mean_tokens=mean_tokens,
        block_size=block_size, num_layers=num_layers,
        num_kv_heads=num_kv_heads, head_dim=head_dim, itemsize=itemsize,
        kv_quant=kv_quant,
    )
    local = kv_capacity_requests(
        hbm_budget_per_shard, max_seq=max_seq, mean_tokens=mean_tokens,
        block_size=block_size, num_layers=num_layers,
        num_kv_heads=local_heads, head_dim=head_dim, itemsize=itemsize,
        kv_quant=kv_quant,
    )
    return {
        "dense": local["dense"],
        "paged": local["paged"],
        "bytes_per_token_per_shard": local["bytes_per_token"],
        "blocks_per_request": local["blocks_per_request"],
        "kv_heads_divide": float(divides),
        "scaling_vs_1": local["paged"] / max(1, base["paged"]),
    }


def _round_up(x: int, mult: int) -> int:
    return mult * math.ceil(x / mult) if mult > 0 else x


def padded_dim(dim: int, tile: int) -> int:
    return _round_up(dim, tile)


def packed_shape(rows: int, cols: int, t0: int, t1: int) -> tuple[int, int, int, int]:
    return (math.ceil(rows / t0), math.ceil(cols / t1), t0, t1)
