"""PackedLinear — the paper's encoding as a first-class parameter format.

Weights of every dense projection are stored in the mmt4d packed layout
(N1, K1, N0, K0), packed ONCE at init/load (the paper packs at compile time;
same amortization).  Autodiff flows through the packed layout directly —
pack/unpack are linear, gradients and optimizer state share the packed shape,
and zero-padding regions provably stay zero under AdamW (sliced outputs give
them zero gradient).

`EncodingConfig.backend` picks the mmt4d implementation per DESIGN.md §3.
`enabled=False` stores plain (N, K) weights and runs the un-encoded reference
contraction — the upstream-IREE baseline used by benchmarks/table2.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import encoding
from repro.core import targets as targets_lib
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class EncodingConfig:
    enabled: bool = True
    backend: str = "xla"        # xla | pallas | fused | reference
    # Attention op-class backend (kernels/registry.py select_attn): "xla"
    # (the jnp references), "pallas" (kernels/attn.py microkernels), or
    # "auto" (tuned table -> static policy -> xla fallback).  Mirrors
    # `backend`'s contract for the matmul class; serving (serve_llama
    # --attn-backend) defaults to "auto".
    attn_backend: str = "xla"
    # Pallas interpret mode: None = auto (interpret only when no TPU backend
    # is present — see targets.resolve_interpret); True/False force it.
    interpret: bool | None = None
    target: targets_lib.TargetSpec = targets_lib.TPU_V5E
    # Pad packed tile counts to divide the mesh axes (16 in production).
    shard_multiple: int = 1
    # Serving weight quantization: "none" | "int8" (w8a8, per-channel/per-row
    # scales — kernels/mmt4d_q8.py) | "int4" (w4a8, per-K-group scales,
    # nibble-packed — kernels/mmt4d_q4.py).  Serving only.
    weight_quant: str = "none"
    # K elements per int4 scale group (weight_quant="int4" only).  Smaller
    # groups buy accuracy with more scale bytes — see docs/PERF.md.
    quant_group: int = 16
    # Cross-shard reduction dtype for contracting-dim-sharded matmuls:
    # "bfloat16" halves the partial-sum all-reduce bytes (in-shard MXU
    # accumulation stays f32; only the K-shard partials are rounded).
    # Applied only when activations are bf16 (production), never in f32 tests.
    reduce_dtype: str = "float32"
    # Perf-hillclimb overrides (None = VMEM-model selection).
    gemm_blocks: tuple[int, int, int] | None = None

    def resolved_backend(self) -> str:
        return self.backend if self.enabled else "reference"


DEFAULT_ENCODING = EncodingConfig()


def linear_init(
    key: jax.Array,
    in_dim: int,
    out_dim: int,
    *,
    enc: EncodingConfig = DEFAULT_ENCODING,
    use_bias: bool = False,
    dtype: Any = jnp.float32,
    scale: float | None = None,
) -> dict:
    """Init a linear layer y = x @ W^T + b, stored packed when encoding is on."""
    scale = scale if scale is not None else in_dim**-0.5
    w_t = scale * jax.random.normal(key, (out_dim, in_dim), dtype=jnp.float32)
    w_t = w_t.astype(dtype)
    params = {}
    if enc.enabled and enc.weight_quant == "int4":
        w_q4, s_w4 = ops.pack_rhs_q4(
            w_t, group=enc.quant_group, shard_multiple=enc.shard_multiple
        )
        params["w_q4"] = w_q4
        params["w_scale4"] = s_w4
    elif enc.enabled and enc.weight_quant == "int8":
        w_q, s_w = ops.pack_rhs_q8(w_t, shard_multiple=enc.shard_multiple)
        params["w_q"] = w_q
        params["w_scale"] = s_w
    elif enc.enabled:
        params["w_packed"] = ops.pack_rhs(
            w_t, target=enc.target, shard_multiple=enc.shard_multiple
        )
    else:
        params["w_t"] = w_t
    if use_bias:
        params["b"] = jnp.zeros((out_dim,), dtype=dtype)
    return params


def linear_apply(
    params: dict,
    x: jnp.ndarray,
    *,
    n: int,
    phase: encoding.Phase,
    enc: EncodingConfig = DEFAULT_ENCODING,
    out_dtype: Any = None,
) -> jnp.ndarray:
    out_dtype = out_dtype or x.dtype
    acc_dtype = jnp.float32
    if enc.reduce_dtype == "bfloat16" and x.dtype == jnp.bfloat16:
        acc_dtype = jnp.bfloat16
    quant_backend = (
        enc.backend if enc.backend in ("pallas", "fused", "auto") else "xla"
    )
    if "w_q4" in params:
        y = ops.encoded_matmul_q4(
            x,
            params["w_q4"],
            params["w_scale4"],
            n=n,
            phase=phase,
            group=enc.quant_group,
            backend=quant_backend,
            target=enc.target,
            out_dtype=out_dtype,
            interpret=enc.interpret,
        )
    elif "w_q" in params:
        y = ops.encoded_matmul_q8(
            x,
            params["w_q"],
            params["w_scale"],
            n=n,
            phase=phase,
            backend=quant_backend,
            target=enc.target,
            out_dtype=out_dtype,
            interpret=enc.interpret,
        )
    elif "w_packed" in params:
        y = ops.encoded_matmul(
            x,
            params["w_packed"],
            n=n,
            phase=phase,
            backend=enc.resolved_backend(),
            blocks=enc.gemm_blocks,
            target=enc.target,
            out_dtype=out_dtype,
            acc_dtype=acc_dtype,
            interpret=enc.interpret,
        )
    else:
        w_t = params["w_t"]
        y = jnp.einsum(
            "...k,nk->...n", x, w_t, preferred_element_type=jnp.float32
        ).astype(out_dtype)
    if "b" in params:
        y = y + params["b"].astype(out_dtype)
    return y


def linear_out_dim(params: dict) -> int:
    for key in ("w_packed", "w_q", "w_q4"):
        if key in params:
            n1, _, n0, _ = params[key].shape
            return n1 * n0  # padded; callers pass the true `n` to linear_apply
    return params["w_t"].shape[0]
