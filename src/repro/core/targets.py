"""Hardware target descriptions.

The paper's `materialize-device-encoding` pass keys tile selection off the
target's vector parameters (VLEN for RVV).  We model the same idea as an
explicit TargetSpec consumed by `select_tile_sizes` and by the roofline
analysis.  TPU v5e is the primary target; the RVV entry documents the paper's
original hardware so the selection logic can be tested against the paper's
published tile sizes.
"""

from __future__ import annotations

import dataclasses
import functools


@dataclasses.dataclass(frozen=True)
class TargetSpec:
    name: str
    # Compute.
    peak_flops_bf16: float  # FLOP/s per chip
    peak_flops_f32: float
    # Memory system.
    hbm_bytes_per_s: float
    hbm_bytes: int
    vmem_bytes: int  # fast on-chip memory usable by one kernel instance
    # Interconnect (per-link, one direction).
    ici_bytes_per_s: float
    # Compute-unit geometry.
    mxu_dim: int  # systolic array edge (matmul native tile)
    lane_count: int  # VREG lanes
    sublane_count: int  # VREG sublanes for 32-bit types


# TPU v5e — the numbers used throughout EXPERIMENTS.md §Roofline.
TPU_V5E = TargetSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=98.5e12,
    hbm_bytes_per_s=819e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=16 * 1024**2,
    ici_bytes_per_s=50e9,
    mxu_dim=128,
    lane_count=128,
    sublane_count=8,
)

# The paper's board (MILK-V Jupiter, SpacemiT K1/X60): VLEN=256-bit RVV.
# Kept so tests can check that our selection rule reproduces the paper's
# published tiles when pointed at the paper's hardware.
RISCV_VLEN256 = TargetSpec(
    name="riscv-rvv-vlen256",
    peak_flops_bf16=2 * 1.66e9 * 16,   # 2 flop/FMA * clock * (VLEN/16 f16 lanes)
    peak_flops_f32=2 * 1.66e9 * 8,
    hbm_bytes_per_s=10.6e9,            # LPDDR4x-ish
    hbm_bytes=8 * 1024**3,
    vmem_bytes=32 * 1024,              # register file + L1 working set proxy
    ici_bytes_per_s=0.0,
    mxu_dim=1,                         # no matrix unit: vector-only
    lane_count=16,                     # VLEN/16 f16 elements per vreg
    sublane_count=1,
)

# RVV VLEN in *bits* for the paper-rule check.
RISCV_VLEN_BITS = 256


def sublanes_for_dtype(target: TargetSpec, itemsize: int) -> int:
    """TPU packs narrow dtypes into deeper sublane tiles: f32→8, bf16→16, i8→32."""
    return target.sublane_count * max(1, 4 // itemsize)


@functools.lru_cache(maxsize=1)
def has_tpu_backend() -> bool:
    """True when JAX's default backend is a real TPU (not CPU/interpret host)."""
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:  # no runtime at all — treat as hostile/CPU environment
        return False


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a Pallas `interpret` request: None = auto-detect.

    Auto mode interprets only when no TPU backend is present, so real-hardware
    runs never silently fall back to interpreted kernels (and CPU containers
    never try to compile Mosaic).
    """
    if interpret is not None:
        return bool(interpret)
    return not has_tpu_backend()
