"""End-to-end serving driver: continuous-batching engine on a reduced model.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --requests 8

Token-budget continuous batching (one mixed chunked-prefill + decode
dispatch per step, serving/engine.py) with streamed output:

  PYTHONPATH=src python -m repro.launch.serve --token-budget 64 \
      --slo-class interactive --stream
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.serving import engine as engine_lib
from repro.serving.config import EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--backend", default="xla", choices=["xla", "pallas", "fused", "reference"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-mode", default="paged", choices=["paged", "dense"])
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="paged pool size; small values force preemption")
    ap.add_argument("--kv-quant", default="bf16",
                    choices=["bf16", "kv8", "kv4"],
                    help="KV-cache storage layout: raw bf16, int8 + "
                         "per-page scales (kv8), or packed int4 (kv4; "
                         "downgrades to kv8 under an xla attention "
                         "fallback)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="radix-tree prefix cache: park finished requests' "
                         "full KV blocks for cross-request longest-common-"
                         "prefix reuse (--no-prefix-cache disables; paged "
                         "cache only)")
    ap.add_argument("--tenant-quota", dest="tenant_quota", type=int,
                    default=None,
                    help="per-tenant page quota (pages): cap any one "
                         "tenant's worst-case page reservation so it "
                         "cannot starve the pool")
    ap.add_argument("--tenants", type=int, default=1,
                    help="number of synthetic tenants; requests are "
                         "assigned round-robin (tenant-0, tenant-1, ...)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-step token budget: run the unified mixed "
                         "chunked-prefill + decode scheduler instead of the "
                         "phase-split engine")
    ap.add_argument("--slo-class", default="standard",
                    choices=sorted(engine_lib.SLO_CLASSES),
                    help="SLO class stamped on every submitted request "
                         "(admission priority under --token-budget)")
    ap.add_argument("--mesh-shape", default="1",
                    help='serving mesh shape: "2" = 2-way tensor parallel, '
                         '"2x4" = 2 data replicas x 4-way TP; the device '
                         "count must cover the product "
                         "(launch/mesh.build_serving_mesh)")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are committed (stream_cb)")
    args = ap.parse_args()

    config = EngineConfig.from_args(args)
    cfg = registry.get_reduced(args.arch)
    enc = EncodingConfig(enabled=True, backend=args.backend, interpret=True)
    params = T.model_init(jax.random.PRNGKey(args.seed), cfg, enc)

    def stream_cb(req, tok):
        print(f"  [stream] req {req.uid} += {tok} "
              f"({len(req.generated)}/{req.max_new_tokens})")

    eng = engine_lib.Engine(
        params, cfg, enc, config=config,
        stream_cb=stream_cb if args.stream else None,
    )
    if eng.config.downgrades or eng.enc_downgrades:
        print(f"[serve] config downgrades: "
              f"{list(eng.config.downgrades) + list(eng.enc_downgrades)}")
    if eng.tp_shards > 1:
        print(f"[serve] tensor parallel: {eng.tp_shards} shards "
              f"(mesh {'x'.join(map(str, eng.config.mesh_shape))})")

    rng = np.random.RandomState(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        plen = rng.randint(args.prompt_len // 2, args.prompt_len + 1)
        prompt = rng.randint(1, cfg.vocab_size, size=plen).astype(np.int32)
        eng.submit(engine_lib.Request(
            uid=i, prompt=prompt, max_new_tokens=args.max_new,
            slo_class=args.slo_class,
            tenant=f"tenant-{i % max(1, args.tenants)}",
        ))
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.2f} tok/s decode throughput incl. prefill)")
    # stats_view(): shape-stable schema — attn_backend/degraded are always
    # {shard -> value} dicts here, whatever the mesh degree.
    stats = eng.stats_view()
    backends = stats["attn_backend"]
    degraded = stats["degraded"]
    print(f"[serve] kv_quant={stats['kv_quant']} attn_backend="
          + ",".join(f"{k}:{v}" for k, v in sorted(backends.items()))
          + f" degraded={sum(len(v) for v in degraded.values())}")
    if stats["cache_mode"] == "paged":
        print(f"[serve] paged: peak_active={stats['peak_active']} "
              f"pages={stats['pages_total']} peak_in_use={stats['peak_in_use']} "
              f"shared_hits={stats['shared_hits']} preemptions={stats['preemptions']}")
        pc = stats["prefix_cache"]
        line = (f"[serve] prefix_cache: enabled={pc['enabled']} "
                f"hit_rate={pc['hit_rate']:.3f} hit_tokens={pc['hit_tokens']} "
                f"cached_pages={pc['cached_pages']} evictions={pc['evictions']} "
                f"deferred_hits={pc['deferred_hits']}")
        if pc.get("tenant_quota") is not None:
            usage = pc.get("tenant_usage", {})
            line += (f" tenant_quota={pc['tenant_quota']} tenants="
                     + ",".join(f"{t}:{u:.1f}"
                                for t, u in sorted(usage.items())))
        print(line)
    if "continuous" in stats:
        c = stats["continuous"]
        print(f"[serve] continuous: budget={c['token_budget']} "
              f"mixed_steps={c['mixed_steps']} decode_stalls={c['decode_stall_steps']} "
              f"prefill_tok={c['prefill_tokens']} decode_tok={c['decode_tokens']}")
    for r in done[: min(4, len(done))]:
        print(f"  req {r.uid}: prompt[:4]={r.prompt[:4].tolist()} -> gen[:8]={r.generated[:8]}")
    return done


if __name__ == "__main__":
    main()
