"""Production meshes.  Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).

`build_serving_mesh` is the serving entry point: it turns
`EngineConfig.mesh_shape` into a concrete device mesh whose trailing axis is
the tensor-parallel axis, and FAILS with an actionable error when the local
device count cannot cover the shape — a serving config that asked for 4
shards must never silently run mesh=1.  On CPU-only hosts the multi-device
path is emulated with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
(set before the first jax import); the CI mesh-conformance job and
tests/test_tp_mesh.py run exactly that way.
"""

from __future__ import annotations

import math

import jax
import numpy as np


def _make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Version-portable mesh construction.  jax >= 0.5 wants explicit
    axis_types; 0.4.x (the pinned CI minimum) has neither AxisType nor the
    axis_types= kwarg, so fall back to the plain device-grid Mesh."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    n = math.prod(shape)
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n]).reshape(shape), axes
    )


# Axis naming per mesh rank: the trailing axis is always the TP axis.
_SERVING_AXES = {1: (), 2: ("data",), 3: ("pod", "data")}


def build_serving_mesh(
    mesh_shape: tuple[int, ...], *, tp_axis: str = "model", devices=None,
):
    """Device mesh for tensor-parallel serving (EngineConfig.mesh_shape).

    The trailing axis of `mesh_shape` is the tensor-parallel degree and is
    named `tp_axis` ("model" — the name parallel/sharding.py's rules key
    on); leading axes are named ("data",) / ("pod", "data") for replica
    dimensions.  Raises ValueError — never a silent mesh=1 — when the
    visible device count cannot supply the requested shape, with the
    CPU-emulation flag spelled out in the message."""
    shape = tuple(int(n) for n in mesh_shape)
    if not shape or any(n < 1 for n in shape):
        raise ValueError(
            f"mesh_shape must be a non-empty tuple of positive ints, "
            f"got {mesh_shape!r}"
        )
    if len(shape) > 3:
        raise ValueError(
            f"mesh_shape supports at most 3 axes, got {mesh_shape!r}"
        )
    devices = list(jax.devices()) if devices is None else list(devices)
    need = math.prod(shape)
    if need > len(devices):
        raise ValueError(
            f"mesh_shape {shape} needs {need} devices but only "
            f"{len(devices)} are visible; shrink the mesh, or (CPU "
            f"emulation) set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={need} BEFORE the first jax import"
        )
    axes = _SERVING_AXES[len(shape)] + (tp_axis,)
    return jax.sharding.Mesh(
        np.asarray(devices[:need]).reshape(shape), axes
    )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh_for(devices: int, *, model_parallel: int = 1):
    """Elastic mesh: largest (data, model) grid for the surviving device count.

    Used by runtime/elastic.py when a slice comes back with fewer chips."""
    model_parallel = max(1, min(model_parallel, devices))
    while devices % model_parallel:
        model_parallel -= 1
    return _make_mesh(
        (devices // model_parallel, model_parallel), ("data", "model")
    )


def data_axes(mesh) -> tuple[str, ...]:
    """Axes carrying the batch (DP/FSDP): ('pod','data') on multi-pod."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a == "model")
