"""Production meshes.  Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh_for(devices: int, *, model_parallel: int = 1):
    """Elastic mesh: largest (data, model) grid for the surviving device count.

    Used by runtime/elastic.py when a slice comes back with fewer chips."""
    model_parallel = max(1, min(model_parallel, devices))
    while devices % model_parallel:
        model_parallel -= 1
    return jax.make_mesh(
        (devices // model_parallel, model_parallel), ("data", "model"), axis_types=_auto(2)
    )


def data_axes(mesh) -> tuple[str, ...]:
    """Axes carrying the batch (DP/FSDP): ('pod','data') on multi-pod."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a == "model")
