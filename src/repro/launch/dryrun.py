import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Deliverable (e): multi-pod dry-run.  Lowers + compiles every
# (architecture x input shape x mesh) cell with ShapeDtypeStruct stand-ins
# (no real allocation), prints memory_analysis / cost_analysis, and records
# the roofline terms consumed by EXPERIMENTS.md §Dry-run / §Roofline.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results/
#
# NOTE the XLA_FLAGS line above MUST run before any jax import: jax locks the
# device count at first init.  Smoke tests and benchmarks never import this
# module, so they keep seeing 1 CPU device.

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.packed import EncodingConfig
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.parallel import sharding
from repro.serving import engine as engine_lib
from repro.train import optimizer as opt_lib
from repro.train import trainer as trainer_lib

PRODUCTION_ENC = EncodingConfig(
    enabled=True, backend="xla", interpret=False, shard_multiple=16
)
# bf16 Adam moments: halves optimizer HBM so the 314B config's train step
# fits 16 GiB/chip at 256 chips (see EXPERIMENTS.md §Dry-run).
PRODUCTION_OPT = opt_lib.OptimizerConfig(moment_dtype="bfloat16")


def _sds(tree, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree,
        shardings,
    )


def batch_struct(cfg: ModelConfig, shape: ShapeConfig, *, with_labels: bool):
    """Abstract input batch for one (arch, shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if cfg.family == "vlm":
        p = cfg.frontend_tokens
        out["tokens"] = jax.ShapeDtypeStruct((b, s - p), jnp.int32)
        out["patches"] = jax.ShapeDtypeStruct((b, p, cfg.frontend_dim), jnp.float32)
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct((b, s - p), jnp.int32)
        return out
    out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    enc: EncodingConfig = PRODUCTION_ENC,
    microbatches: int = 1,
    cfg_overrides: dict | None = None,
    enc_overrides: dict | None = None,
):
    """Returns (lowered, mesh, meta) for one dry-run cell."""
    import dataclasses

    cfg = registry.get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    if enc_overrides:
        enc = dataclasses.replace(enc, **enc_overrides)
    shape = registry.get_shape(shape_name)
    ok, why = registry.cell_is_runnable(cfg, shape)
    if not ok:
        raise SkipCell(why)

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    with jax.set_mesh(mesh):
        params_shape = jax.eval_shape(
            lambda k: T.model_init(k, cfg, enc), jax.random.PRNGKey(0)
        )
        p_sh = sharding.params_shardings(params_shape, mesh)
        params = _sds(params_shape, p_sh)

        if shape.kind == "train":
            opt_shape = jax.eval_shape(
                lambda p: opt_lib.init(p, PRODUCTION_OPT), params_shape
            )
            o_sh = sharding.params_shardings(
                {"mu": params_shape, "nu": params_shape}, mesh
            )
            o_sh = {**o_sh, "step": sharding.replicated(mesh)}
            opt_state = _sds(opt_shape, o_sh)
            bstruct = batch_struct(cfg, shape, with_labels=True)
            b_sh = sharding.batch_shardings(bstruct, mesh)
            batch = _sds(bstruct, b_sh)
            step_fn = trainer_lib.make_train_step(
                cfg, enc, PRODUCTION_OPT, microbatches=microbatches
            )
            fn = lambda p, o, b: step_fn(p, o, b)[:3]
            lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(params, opt_state, batch)
        elif shape.kind == "prefill":
            caches_shape = jax.eval_shape(
                lambda: T.cache_init(cfg, shape.global_batch, shape.seq_len)
            )
            c_sh = sharding.cache_shardings(caches_shape, mesh)
            caches = _sds(caches_shape, c_sh)
            bstruct = batch_struct(cfg, shape, with_labels=False)
            b_sh = sharding.batch_shardings(bstruct, mesh)
            batch = _sds(bstruct, b_sh)
            prefill = engine_lib.make_prefill_step(cfg, enc)
            extras_keys = [k for k in bstruct if k != "tokens"]

            def fn(p, tokens, caches, extras):
                return prefill(p, tokens, caches, extras)

            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                params,
                batch["tokens"],
                caches,
                {k: batch[k] for k in extras_keys},
            )
        else:  # decode
            caches_shape = jax.eval_shape(
                lambda: T.cache_init(cfg, shape.global_batch, shape.seq_len)
            )
            c_sh = sharding.cache_shardings(caches_shape, mesh)
            caches = _sds(caches_shape, c_sh)
            token = jax.ShapeDtypeStruct(
                (shape.global_batch, 1),
                jnp.int32,
                sharding=sharding.batch_shardings(
                    jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32), mesh
                ),
            )
            pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=sharding.replicated(mesh))
            decode = engine_lib.make_decode_step(cfg, enc)
            lowered = jax.jit(decode, donate_argnums=(1,)).lower(params, caches, token, pos)

    meta = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "kind": shape.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "n_groups": cfg.num_layers // len(cfg.block_pattern),
    }
    return lowered, mesh, meta


class SkipCell(Exception):
    pass


def run_cell(arch, shape_name, *, multi_pod, save_hlo_dir=None, hlo_suffix="", **kw):
    t0 = time.time()
    lowered, mesh, meta = lower_cell(arch, shape_name, multi_pod=multi_pod, **kw)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    from benchmarks import hlo_analysis

    hlo = compiled.as_text()
    # NOTE: XLA's cost_analysis() does not multiply while-loop trip counts
    # (lax.scan bodies count once), so flops/bytes come from our own HLO
    # analyzer with loop-multiplier propagation (benchmarks/hlo_analysis.py).
    a = hlo_analysis.analyze(hlo)
    if save_hlo_dir:
        import gzip

        os.makedirs(save_hlo_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{meta['mesh']}{hlo_suffix}".replace("/", "_")
        with gzip.open(os.path.join(save_hlo_dir, tag + ".hlo.txt.gz"), "wt") as f:
            f.write(hlo)

    result = {
        **meta,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": a["flops"],
        "bytes_per_device": a["hbm_bytes"],
        "bytes_per_device_unfused": a["hbm_bytes_unfused"],
        "collective_bytes_per_device": a["collective_bytes"],
        "collective_ops": a["collective_counts"],
        "collective_per_op": a["collective_per_op"],
        "xla_cost_flops_unscaled": float(cost.get("flops", 0.0)),
        "memory": mem_info,
    }
    return result


ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--save-hlo", default=None)
    # Perf levers (§Perf hillclimb variants).
    ap.add_argument("--expand-kv", action="store_true")
    ap.add_argument("--pad-heads", type=int, default=0)
    ap.add_argument("--causal-bands", type=int, default=0)
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--moe-shard-map", action="store_true")
    ap.add_argument("--moe-dense-decode", action="store_true")
    ap.add_argument("--quant-int8", action="store_true",
                    help="int8 w8a8 serving weights (decode/prefill cells)")
    ap.add_argument("--quant-int4", action="store_true",
                    help="group int4 w4a8 serving weights (kernels/mmt4d_q4)")
    ap.add_argument("--reduce-bf16", action="store_true",
                    help="bf16 cross-shard matmul reductions")
    ap.add_argument(
        "--production", action="store_true",
        help="all confirmed §Perf levers: expand-kv+pad16, causal-bands 4, "
             "moe shard_map dispatch, dense-decode MoE",
    )
    ap.add_argument("--q-chunk", type=int, default=0)
    ap.add_argument("--kv-chunk", type=int, default=0)
    ap.add_argument("--tag", default="", help="suffix for result filenames")
    args = ap.parse_args()

    overrides = {}
    if args.production:
        overrides.update(
            tp_attn_expand_kv=True,
            pad_attn_heads_to=16,
            causal_bands=4,
            moe_shard_map=True,
            moe_dense_decode=True,
        )
    if args.expand_kv:
        overrides["tp_attn_expand_kv"] = True
    if args.pad_heads:
        overrides["pad_attn_heads_to"] = args.pad_heads
    if args.causal_bands:
        overrides["causal_bands"] = args.causal_bands
    if args.moe_groups:
        overrides["moe_dispatch_groups"] = args.moe_groups
    if args.moe_shard_map:
        overrides["moe_shard_map"] = True
    if args.moe_dense_decode:
        overrides["moe_dense_decode"] = True
    if args.q_chunk:
        overrides["q_chunk"] = args.q_chunk
    if args.kv_chunk:
        overrides["kv_chunk"] = args.kv_chunk
    enc_overrides = {}
    if args.quant_int8:
        enc_overrides["weight_quant"] = "int8"
    if args.quant_int4:
        enc_overrides["weight_quant"] = "int4"
    if args.reduce_bf16:
        # NOTE kept out of --production: measured ineffective — GSPMD
        # all-reduces its internal f32 dot accumulator regardless of the
        # requested einsum output dtype (EXPERIMENTS.md §Perf A/B final
        # iterations).  A shard_map row-parallel matmul with explicit bf16
        # psum is the real lever (future work).
        enc_overrides["reduce_dtype"] = "bfloat16"
    enc_overrides = enc_overrides or None

    cells = []
    archs = registry.ASSIGNED_ARCHS if (args.all or not args.arch) else (args.arch,)
    shapes = ALL_SHAPES if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                cells.append((arch, shp, mp))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shp, mp in cells:
        tag = f"{arch}_{shp}_{'2x16x16' if mp else '16x16'}{args.tag}"
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path):
            print(f"[skip-cached] {tag}")
            continue
        try:
            res = run_cell(
                arch, shp, multi_pod=mp,
                microbatches=args.microbatches, save_hlo_dir=args.save_hlo,
                hlo_suffix=args.tag, cfg_overrides=overrides or None,
                enc_overrides=enc_overrides,
            )
            res["variant"] = args.tag or "baseline"
            with open(out_path, "w") as f:
                json.dump(res, f, indent=1)
            print(
                f"[ok] {tag}: compile={res['compile_s']}s "
                f"flops/dev={res['flops_per_device']:.3e} "
                f"bytes/dev={res['bytes_per_device']:.3e} "
                f"coll/dev={res['collective_bytes_per_device']:.3e}"
            )
        except SkipCell as e:
            with open(out_path, "w") as f:
                json.dump({"arch": arch, "shape": shp, "skipped": str(e)}, f)
            print(f"[skip] {tag}: {e}")
        except Exception as e:
            failures += 1
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
