"""End-to-end training driver.

CPU-scale by default (reduced config): trains a ~small model for N steps with
checkpointing, restart recovery, straggler watchdog, and optional gradient
compression — the same code path the production mesh would run under pjit.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 300 \
      --d-model 256 --layers 8   # ~100M-class run (examples/train_100m.py)
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs import registry
from repro.core.packed import EncodingConfig
from repro.data import pipeline as data_lib
from repro.models import transformer as T
from repro.parallel import compression
from repro.runtime import watchdog as wd_lib
from repro.train import optimizer as opt_lib
from repro.train import trainer as trainer_lib


def build(args):
    cfg = registry.get_reduced(args.arch) if args.reduced else registry.get_config(args.arch)
    over = {}
    if args.d_model:
        over.update(
            d_model=args.d_model,
            num_heads=max(4, args.d_model // 64),
            num_kv_heads=max(1, args.d_model // 128),
            head_dim=64,
            d_ff=args.d_ff or 4 * args.d_model,
            rnn_width=args.d_model if cfg.rnn_width else 0,
        )
    if args.layers:
        over["num_layers"] = args.layers
    if args.vocab:
        over["vocab_size"] = args.vocab
    if over:
        cfg = dataclasses.replace(cfg, **over)
    enc = EncodingConfig(
        enabled=not args.no_encoding,
        backend=args.backend,
        interpret=True,
    )
    return cfg, enc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--backend", default="xla", choices=["xla", "pallas", "fused", "reference"])
    ap.add_argument("--no-encoding", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, enc = build(args)
    print(f"[train] arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"params~{cfg.param_count()/1e6:.1f}M backend={args.backend} "
          f"encoding={'on' if enc.enabled else 'off'}")

    opt_cfg = opt_lib.OptimizerConfig(
        peak_lr=args.lr, warmup_steps=max(5, args.steps // 20), decay_steps=args.steps
    )
    params = T.model_init(jax.random.PRNGKey(args.seed), cfg, enc)
    opt_state = opt_lib.init(params)
    comp_state = compression.init_state(params) if args.compress_grads else None

    start = 0
    if args.ckpt_dir:
        latest = ckpt_lib.latest_step(args.ckpt_dir)
        if latest is not None:
            state = ckpt_lib.restore(
                args.ckpt_dir, latest, {"params": params, "opt": opt_state}
            )
            params, opt_state = state["params"], state["opt"]
            start = latest
            print(f"[train] resumed from step {start}")

    data = data_lib.SyntheticPacked(
        data_lib.DataConfig(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    )
    step_fn = jax.jit(
        trainer_lib.make_train_step(
            cfg, enc, opt_cfg,
            microbatches=args.microbatches,
            compress_grads=args.compress_grads,
        )
    )
    saver = ckpt_lib.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    watchdog = wd_lib.StepWatchdog()

    losses = []
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.batch(step))
        watchdog.step_start()
        params, opt_state, metrics, comp_state = step_fn(
            params, opt_state, batch, comp_state
        )
        loss = float(metrics["loss"])
        watchdog.step_end()
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step={step} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"ewma_s={watchdog.ewma:.3f}" if watchdog.ewma else "")
        if saver and (step + 1) % args.ckpt_every == 0:
            saver.save({"params": params, "opt": opt_state}, step + 1)
    if saver:
        saver.save({"params": params, "opt": opt_state}, args.steps)
        saver.wait()
    print(f"[train] done. first-10 mean={np.mean(losses[:10]):.4f} "
          f"last-10 mean={np.mean(losses[-10:]):.4f}")
    return losses


if __name__ == "__main__":
    main()
