"""Block-paged KV-cache allocator — the serving memory plan behind Engine's
cache_mode="paged".

The dense engine reserves a worst-case (slots, max_seq) KV row per slot; HBM
is spent on sequence positions that mostly never exist (short prompts, early
decode).  The paged plan instead carves the per-layer cache into a global pool
of fixed-size pages (`block_size` tokens each) and gives every slot a block
table mapping logical block j -> physical page.  Capacity then scales with
TOKENS IN FLIGHT, not slots x max_seq (core/encoding.py has the math; the
capacity-vs-dense sweep lives in benchmarks/table2_throughput.py).

This module is the host-side bookkeeping only (pure numpy/python — nothing
here is traced):

  * free-list page allocation with exact refcounts,
  * a prefix registry: immutable full blocks of a prompt are keyed by their
    token prefix; a later request with the same leading tokens maps its
    leading blocks to the SAME physical pages (shared, refcount++) instead of
    allocating, and takes a private page from the first block that diverges
    (or is still appendable) — copy-on-write at the first divergent block,
  * audit() — the invariant checker the allocator tests drive.

Only FULL blocks that can never be written again are shareable: decode
re-writes position plen-1 (the engine's first decode step recomputes the last
prompt token's K/V), so a prompt of length P shares at most its first
(P-1)//block_size blocks; everything from the first divergent or appendable
block on is private to the slot.  Page 0 is a reserved scratch page: idle
decode rows point their writes at it, and it is never allocated.
"""

from __future__ import annotations

import dataclasses

import numpy as np

SCRATCH_PAGE = 0


class AllocatorInvariantError(AssertionError):
    """A page-accounting invariant broke: double free, refcount underflow,
    sharing an unreferenced page, or a stale prefix-registry reference.
    Carries the page id and (when the engine told the allocator) the slot
    that owned the page, so a leak report names the request lifecycle path
    that dropped it.  Subclasses AssertionError: every pre-existing
    `pytest.raises(AssertionError)` / audit() contract still holds."""

    def __init__(self, message: str, *, page: int | None = None,
                 owner: int | None = None):
        suffix = ""
        if page is not None:
            suffix = f" (page {page}" + (
                f", owning slot {owner})" if owner is not None else ")"
            )
        super().__init__(message + suffix)
        self.page = page
        self.owner = owner


@dataclasses.dataclass
class PagePlan:
    """Physical pages covering one prompt, leading `shared` pages reused."""

    pages: list[int]
    shared: list[bool]

    @property
    def new_pages(self) -> list[int]:
        return [p for p, sh in zip(self.pages, self.shared) if not sh]


class BlockAllocator:
    """Fixed pool of `num_pages` pages of `block_size` tokens (page 0 scratch)."""

    def __init__(self, num_pages: int, block_size: int,
                 kv_quant: str = "bf16"):
        assert num_pages >= 2, "need at least one allocatable page + scratch"
        assert block_size > 0 and (block_size & (block_size - 1)) == 0, (
            "block_size must be a power of two (prefill pads to block multiples)"
        )
        self.num_pages = num_pages
        self.block_size = block_size
        self.kv_quant = kv_quant
        # LIFO free list: lowest page ids first, scratch excluded.
        self.free: list[int] = list(range(num_pages - 1, SCRATCH_PAGE, -1))
        self.refcount = np.zeros(num_pages, np.int32)
        self.registry: dict[bytes, int] = {}   # token-prefix key -> page
        self.page_key: dict[int, bytes] = {}   # page -> its registry key
        # Last slot the engine charged each live page to (diagnostics only:
        # AllocatorInvariantError names it; shared pages keep the first owner).
        self.page_owner: dict[int, int] = {}
        # Pages whose per-page dequant scales are live (kv8/kv4 layouts only).
        # Scale pages live at the SAME page ids as their data pages, so this
        # set must track the allocated set in lockstep: a page handed out
        # without scale state would dequantize someone else's magnitudes.
        self.scale_live: set[int] = set()
        self.stats = {
            "allocs": 0, "frees": 0, "shared_hits": 0, "cow_events": 0,
            "peak_in_use": 0,
        }

    @property
    def _quantized(self) -> bool:
        return self.kv_quant != "bf16"

    # -- capacity ------------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.num_pages - 1

    def available(self) -> int:
        return len(self.free)

    def in_use(self) -> int:
        return self.capacity - len(self.free)

    def blocks_for_tokens(self, tokens: int) -> int:
        return max(1, -(-tokens // self.block_size))

    # -- raw page ops --------------------------------------------------------

    def alloc(self, *, owner: int | None = None) -> int | None:
        if not self.free:
            return None
        page = self.free.pop()
        if self.refcount[page] != 0:
            raise AllocatorInvariantError(
                "free-list page has live refcount "
                f"{int(self.refcount[page])}", page=page,
                owner=self.page_owner.get(page),
            )
        self.refcount[page] = 1
        if self._quantized:
            self.scale_live.add(page)
        if owner is not None:
            self.page_owner[page] = owner
        self.stats["allocs"] += 1
        self.stats["peak_in_use"] = max(self.stats["peak_in_use"], self.in_use())
        return page

    def share(self, page: int, *, owner: int | None = None) -> int:
        if self.refcount[page] <= 0:
            raise AllocatorInvariantError(
                "sharing unreferenced page", page=page,
                owner=self.page_owner.get(page),
            )
        if self._quantized and page not in self.scale_live:
            raise AllocatorInvariantError(
                "sharing a page without live scale state", page=page,
                owner=self.page_owner.get(page),
            )
        self.refcount[page] += 1
        self.stats["shared_hits"] += 1
        if owner is not None:
            self.page_owner.setdefault(page, owner)
        return page

    def free_page(self, page: int, *, owner: int | None = None) -> None:
        if page == SCRATCH_PAGE:
            return
        if self.refcount[page] <= 0:
            # Double free / refcount underflow: typed, with the page id and
            # the slot that last owned it — the leak report the chaos harness
            # (docs/ROBUSTNESS.md) pins failures on.
            raise AllocatorInvariantError(
                "double free (refcount underflow)", page=page,
                owner=owner if owner is not None else self.page_owner.get(page),
            )
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            key = self.page_key.pop(page, None)
            if key is not None and self.registry.get(key) == page:
                del self.registry[key]
            self.page_owner.pop(page, None)
            self.scale_live.discard(page)
            self.free.append(page)
            self.stats["frees"] += 1

    # -- prompt planning (prefix reuse + copy-on-write) ----------------------

    def _key(self, prompt: np.ndarray, j: int) -> bytes:
        """Registry key for block j: the FULL token prefix through its end —
        chained identity, so equal keys imply equal K/V content."""
        return np.ascontiguousarray(
            np.asarray(prompt[: (j + 1) * self.block_size], np.int32)
        ).tobytes()

    def shareable_blocks(self, prompt_len: int) -> int:
        """Blocks of this prompt that are immutable under decode (the engine's
        first decode step re-writes position prompt_len - 1)."""
        return max(0, (prompt_len - 1) // self.block_size)

    def plan_prompt(self, prompt: np.ndarray) -> tuple[int, dict[int, int]]:
        """(total blocks covering the prompt, {block j -> reusable page})."""
        nblocks = self.blocks_for_tokens(len(prompt))
        shared: dict[int, int] = {}
        for j in range(self.shareable_blocks(len(prompt))):
            page = self.registry.get(self._key(prompt, j))
            if page is None:
                break  # chained keys: later blocks cannot match either
            shared[j] = page
        return nblocks, shared

    def commit_prompt(
        self, prompt: np.ndarray, nblocks: int, shared: dict[int, int]
    ) -> PagePlan | None:
        """Materialize a plan: refcount shared pages, allocate private ones,
        register newly-written immutable blocks.  Returns None (and rolls
        back) if the pool cannot cover the private blocks."""
        pages: list[int] = []
        is_shared: list[bool] = []
        immutable = self.shareable_blocks(len(prompt))
        cow_done = False
        for j in range(nblocks):
            if j in shared:
                pages.append(self.share(shared[j]))
                is_shared.append(True)
                continue
            page = self.alloc()
            if page is None:
                for p, sh in zip(pages, is_shared):
                    self.free_page(p)
                return None
            if shared and not cow_done:
                # First private block after a shared prefix: the
                # copy-on-write point (divergent or appendable block).
                self.stats["cow_events"] += 1
                cow_done = True
            if j < immutable:
                key = self._key(prompt, j)
                self.registry[key] = page
                self.page_key[page] = key
            pages.append(page)
            is_shared.append(False)
        return PagePlan(pages=pages, shared=is_shared)

    def free_pages(self, pages: list[int], *, owner: int | None = None) -> None:
        for p in pages:
            self.free_page(p, owner=owner)

    def claim_owner(self, pages: list[int], owner: int) -> None:
        """Record which slot a plan's pages now serve (diagnostics for
        AllocatorInvariantError; shared pages keep their first owner)."""
        for p in pages:
            self.page_owner.setdefault(p, owner)

    # -- invariants ----------------------------------------------------------

    def audit(self, tables_in_use: list[list[int]]) -> None:
        """Raises AssertionError unless the allocator state is exactly
        consistent with the referenced tables:

          * every referenced page is allocated, never on the free list,
          * refcounts equal the number of table references exactly,
          * a page referenced by two tables is in the prefix registry
            (sharing happens only through prefix reuse),
          * the token-prefix registry holds no refs to freed pages (a stale
            registry entry would hand a future prompt a recycled page whose
            K/V belongs to someone else — silent cross-request corruption),
          * free + in-use partitions the pool (scratch excluded),
          * under a quantized layout (kv8/kv4), scale state exactly tracks
            the allocated set: every referenced page has live scales, no
            free page does (spec-decode rollback and COW must free/copy
            scale pages in lockstep with their data pages)."""
        refs: dict[int, int] = {}
        for table in tables_in_use:
            for p in table:
                assert p != SCRATCH_PAGE, "scratch page referenced as data"
                refs[p] = refs.get(p, 0) + 1
        free_set = set(self.free)
        assert len(free_set) == len(self.free), "duplicate pages on free list"
        for p, n in refs.items():
            assert p not in free_set, f"page {p} both referenced and free"
            assert self.refcount[p] == n, (
                f"page {p}: refcount {self.refcount[p]} != {n} references"
            )
            if n > 1:
                assert p in self.page_key, f"page {p} multiply-owned unregistered"
        for p in range(1, self.num_pages):
            if p not in refs:
                if self.refcount[p] != 0:
                    raise AllocatorInvariantError(
                        f"page leaked (rc={int(self.refcount[p])}, "
                        "unreferenced)", page=p, owner=self.page_owner.get(p),
                    )
                assert p in free_set, f"page {p} neither free nor referenced"
        assert len(free_set) + len(refs) == self.capacity
        # The prefix registry must reference only live pages, consistently:
        # a freed page left registered would be handed to a future prompt as
        # "already holding your prefix K/V" after recycling.
        for key, p in self.registry.items():
            if p in free_set or self.refcount[p] <= 0:
                raise AllocatorInvariantError(
                    "prefix registry references a freed page", page=p,
                    owner=self.page_owner.get(p),
                )
            assert self.page_key.get(p) == key, (
                f"registry/page_key disagree for page {p}"
            )
        if self._quantized:
            for p in refs:
                if p not in self.scale_live:
                    raise AllocatorInvariantError(
                        "referenced page lacks live scale state", page=p,
                        owner=self.page_owner.get(p),
                    )
            for p in self.scale_live:
                if p in free_set or self.refcount[p] <= 0:
                    raise AllocatorInvariantError(
                        "freed page still holds scale state", page=p,
                        owner=self.page_owner.get(p),
                    )


class ShardedBlockAllocator:
    """Per-shard paged bookkeeping for tensor-parallel serving.

    Under head-parallel attention every shard holds ITS OWN head-slice of
    every KV page, so each shard owns a full per-shard pool and block table
    — but page IDENTITY must agree across shards (the block table threaded
    into the SPMD dispatch is one logical table; shard k's gather of page p
    must read shard k's slice of the same request's history).  This class
    drives one `BlockAllocator` per shard in lockstep: every operation
    (alloc, share, free, prompt plan/commit) is applied to all shards and
    the results are asserted identical.  BlockAllocator is deterministic by
    construction (LIFO free list, exact refcounts, chained prefix keys), so
    mirrored shards can only diverge through a bookkeeping bug — which this
    class converts into an `AllocatorInvariantError` naming the shard,
    instead of silent cross-shard KV corruption.

    COW, preemption, and `audit()` therefore stay SHARD-LOCAL: each shard's
    allocator proves its own exact partition (per-shard audit is what
    tests/test_tp_mesh.py pins after preemption/replay), while the engine
    keeps exactly one host block table.  The interface mirrors
    BlockAllocator, so Engine code is allocator-agnostic."""

    def __init__(self, num_pages: int, block_size: int, *, shards: int,
                 kv_quant: str = "bf16"):
        assert shards >= 1, shards
        self.shards = [BlockAllocator(num_pages, block_size, kv_quant)
                       for _ in range(shards)]
        self.num_pages = num_pages
        self.block_size = block_size
        self.kv_quant = kv_quant

    @property
    def _p(self) -> BlockAllocator:
        return self.shards[0]

    def _mirror(self, results, what: str):
        first = results[0]
        for k, r in enumerate(results[1:], start=1):
            if r != first:
                raise AllocatorInvariantError(
                    f"shard allocators diverged on {what}: shard 0 -> "
                    f"{first!r}, shard {k} -> {r!r}"
                )
        return first

    # -- capacity (identical across shards by construction) ------------------

    @property
    def capacity(self) -> int:
        return self._p.capacity

    def available(self) -> int:
        return self._mirror([a.available() for a in self.shards], "available")

    def in_use(self) -> int:
        return self._p.in_use()

    def blocks_for_tokens(self, tokens: int) -> int:
        return self._p.blocks_for_tokens(tokens)

    def shareable_blocks(self, prompt_len: int) -> int:
        return self._p.shareable_blocks(prompt_len)

    # -- mirrored page ops ----------------------------------------------------

    def alloc(self, *, owner: int | None = None) -> int | None:
        return self._mirror(
            [a.alloc(owner=owner) for a in self.shards], "alloc"
        )

    def share(self, page: int, *, owner: int | None = None) -> int:
        return self._mirror(
            [a.share(page, owner=owner) for a in self.shards], "share"
        )

    def free_page(self, page: int, *, owner: int | None = None) -> None:
        for a in self.shards:
            a.free_page(page, owner=owner)

    def free_pages(self, pages: list[int], *, owner: int | None = None) -> None:
        for a in self.shards:
            a.free_pages(pages, owner=owner)

    def claim_owner(self, pages: list[int], owner: int) -> None:
        for a in self.shards:
            a.claim_owner(pages, owner)

    # -- mirrored prompt planning ---------------------------------------------

    def plan_prompt(self, prompt: np.ndarray) -> tuple[int, dict[int, int]]:
        return self._mirror(
            [a.plan_prompt(prompt) for a in self.shards], "plan_prompt"
        )

    def commit_prompt(
        self, prompt: np.ndarray, nblocks: int, shared: dict[int, int]
    ) -> PagePlan | None:
        plans = [a.commit_prompt(prompt, nblocks, shared) for a in self.shards]
        self._mirror(
            [(p.pages, p.shared) if p is not None else None for p in plans],
            "commit_prompt",
        )
        return plans[0]

    # -- observability / invariants -------------------------------------------

    @property
    def stats(self) -> dict:
        """Shard-0 counters (mirrors are identical — asserted on every
        mutating op) plus the shard count, so engine stats stay one dict."""
        return {**self._p.stats, "tp_shards": len(self.shards)}

    def per_shard_stats(self) -> list[dict]:
        return [dict(a.stats) for a in self.shards]

    def audit(self, tables_in_use: list[list[int]]) -> None:
        """Run the exact-partition audit on EVERY shard's allocator: each
        shard must independently account for the same referenced tables."""
        for k, a in enumerate(self.shards):
            try:
                a.audit(tables_in_use)
            except AssertionError as exc:
                raise AllocatorInvariantError(
                    f"shard {k} audit failed: {exc}"
                ) from exc
