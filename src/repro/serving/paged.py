"""Block-paged KV-cache allocator — the serving memory plan behind Engine's
cache_mode="paged".

The dense engine reserves a worst-case (slots, max_seq) KV row per slot; HBM
is spent on sequence positions that mostly never exist (short prompts, early
decode).  The paged plan instead carves the per-layer cache into a global pool
of fixed-size pages (`block_size` tokens each) and gives every slot a block
table mapping logical block j -> physical page.  Capacity then scales with
TOKENS IN FLIGHT, not slots x max_seq (core/encoding.py has the math; the
capacity-vs-dense sweep lives in benchmarks/table2_throughput.py).

This module is the host-side bookkeeping only (pure numpy/python — nothing
here is traced):

  * free-list page allocation with exact refcounts,
  * a RADIX-TREE prefix cache over token-block keys: each tree node is one
    immutable full block, keyed by its block-local tokens under its parent
    (chained identity — equal root paths imply equal K/V content).  Admission
    walks the tree for the longest-common-prefix run of full blocks, so a
    prompt sharing 31 of 32 leading blocks reuses 31 pages (the old flat
    registry only matched an exact whole prefix, reusing nothing there),
  * cache retention: when a finished request releases an immutable written
    block whose refcount hits 0, the page is PARKED in the tree (state
    "cached") instead of freed — a later prompt revives it via share(),
  * refcount-aware LRU eviction: alloc() on a dry free list evicts the
    coldest cached tree LEAF first (never a refcount>0 page, never a chain
    interior), so cold chains unwind tip-first and eviction only ever runs
    when the alternative is failing the alloc or preempting live work,
  * per-tenant accounting: every reference is charged to a tenant; private
    pages charge 1, shared pages 1/refcount, and eviction prefers cold
    chains parked by tenants over their page quota,
  * audit() — the invariant checker the allocator tests drive, including
    tree<->pool cross-invariants.

Only FULL blocks that can never be written again are shareable: decode
re-writes position plen-1 (the engine's first decode step recomputes the last
prompt token's K/V), so a prompt of length P shares at most its first
(P-1)//block_size blocks; everything from the first divergent or appendable
block on is private to the slot.  Page 0 is a reserved scratch page: idle
decode rows point their writes at it, and it is never allocated.

Page state machine (scratch excluded):

    free (on free list, rc==0)
      -- alloc() -->            referenced (rc>=1)
    referenced
      -- free_page() to rc==0, registered+written, prefix_cache on -->
                                cached (rc==0, allocated, parked in tree)
      -- free_page() to rc==0 otherwise -->  free
    cached
      -- share() (revival: a cache hit) -->  referenced
      -- eviction inside alloc() -->         free

Quantized layouts (kv8/kv4) keep `scale_live` in lockstep with the ALLOCATED
set — referenced and cached alike: a cached page's scales must survive until
eviction, or revival would dequantize with someone else's magnitudes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

SCRATCH_PAGE = 0
DEFAULT_TENANT = "default"


class AllocatorInvariantError(AssertionError):
    """A page-accounting invariant broke: double free, refcount underflow,
    sharing an unreferenced page, or a stale prefix-cache reference.
    Carries the page id and (when the engine told the allocator) the slot
    that owned the page, so a leak report names the request lifecycle path
    that dropped it.  Subclasses AssertionError: every pre-existing
    `pytest.raises(AssertionError)` / audit() contract still holds."""

    def __init__(self, message: str, *, page: int | None = None,
                 owner: int | None = None):
        suffix = ""
        if page is not None:
            suffix = f" (page {page}" + (
                f", owning slot {owner})" if owner is not None else ")"
            )
        super().__init__(message + suffix)
        self.page = page
        self.owner = owner


@dataclasses.dataclass
class PagePlan:
    """Physical pages covering one prompt, leading `shared` pages reused."""

    pages: list[int]
    shared: list[bool]

    @property
    def new_pages(self) -> list[int]:
        return [p for p, sh in zip(self.pages, self.shared) if not sh]


class _RadixNode:
    """One immutable full block in the prefix tree.

    `key` is the BLOCK-LOCAL token bytes (this block's tokens only): chained
    node identity gives whole-prefix identity, so per-node keys cost
    O(block_size) bytes instead of the old registry's O(prefix) whole-prefix
    keys, and reaping a released page is O(1) through `node_of_page` instead
    of a whole-prefix key round trip."""

    __slots__ = ("key", "page", "parent", "children", "last_use", "tenant")

    def __init__(self, key: bytes, page: int | None,
                 parent: "_RadixNode | None", tenant: str | None):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: dict[bytes, _RadixNode] = {}
        self.last_use = 0
        self.tenant = tenant


class BlockAllocator:
    """Fixed pool of `num_pages` pages of `block_size` tokens (page 0 scratch)."""

    def __init__(self, num_pages: int, block_size: int,
                 kv_quant: str = "bf16", *, prefix_cache: bool = True,
                 tenant_quota: int | None = None):
        assert num_pages >= 2, "need at least one allocatable page + scratch"
        assert block_size > 0 and (block_size & (block_size - 1)) == 0, (
            "block_size must be a power of two (prefill pads to block multiples)"
        )
        self.num_pages = num_pages
        self.block_size = block_size
        self.kv_quant = kv_quant
        self.prefix_cache = prefix_cache
        self.tenant_quota = tenant_quota
        # LIFO free list: lowest page ids first, scratch excluded.
        self.free: list[int] = list(range(num_pages - 1, SCRATCH_PAGE, -1))
        self.refcount = np.zeros(num_pages, np.int32)
        # Radix tree over token-block keys; the root is a pageless sentinel.
        self.root = _RadixNode(b"", None, None, None)
        self.node_of_page: dict[int, _RadixNode] = {}
        # Pages parked in the tree at refcount 0 (allocated, reclaimable).
        self.cached: set[int] = set()
        # Pages whose KV content has actually landed in the pool (the engine
        # marks them after scatter/chunk commit).  Only written pages may be
        # retained: a registered-but-unwritten page is an in-flight promise,
        # not reusable content.
        self.written: set[int] = set()
        # Last slot the engine charged each live page to (diagnostics only:
        # AllocatorInvariantError names it; shared pages keep the first owner).
        self.page_owner: dict[int, int] = {}
        # Pages whose per-page dequant scales are live (kv8/kv4 layouts only).
        # Scale pages live at the SAME page ids as their data pages, so this
        # set must track the ALLOCATED set (referenced + cached) in lockstep:
        # a page handed out without scale state would dequantize someone
        # else's magnitudes, and a cached page without scales could not be
        # revived.
        self.scale_live: set[int] = set()
        # page -> {tenant: live references}; sums to refcount exactly.
        self._tenant_refs: dict[int, dict[str, int]] = {}
        self._tick = 0
        self.stats = {
            "allocs": 0, "frees": 0, "shared_hits": 0, "cow_events": 0,
            "peak_in_use": 0, "evictions": 0, "hit_blocks": 0,
            "hit_tokens": 0, "lookup_blocks": 0, "cached_pages": 0,
        }

    @property
    def _quantized(self) -> bool:
        return self.kv_quant != "bf16"

    # -- capacity ------------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.num_pages - 1

    def available(self) -> int:
        """Pages obtainable without preempting live work: the free list plus
        cached pages evictable leaf-first (a cached page pinned under a live
        chain interior is excluded until the chain above it drains)."""
        return len(self.free) + self._evictable(frozenset())

    def in_use(self) -> int:
        """Pages referenced by live requests (refcount > 0).  Cached pages
        are reclaimable pool headroom, not in-use."""
        return self.capacity - len(self.free) - len(self.cached)

    def blocks_for_tokens(self, tokens: int) -> int:
        return max(1, -(-tokens // self.block_size))

    def _evictable(self, exclude: frozenset) -> int:
        """Cached pages reclaimable by repeated leaf-first eviction.  A
        cached ancestor of a referenced (or `exclude`-reserved) node is
        pinned: evicting it would orphan a live chain."""
        if not self.cached:
            return 0
        pinned: set[int] = set()
        for p, node in self.node_of_page.items():
            if self.refcount[p] > 0 or p in exclude:
                n = node.parent
                while n is not None and n.page is not None \
                        and n.page not in pinned:
                    pinned.add(n.page)
                    n = n.parent
        return sum(1 for p in self.cached
                   if p not in pinned and p not in exclude)

    def plan_fits(self, nblocks: int, shared: dict[int, int]) -> bool:
        """Whether commit_prompt(nblocks, shared) can succeed right now.
        The plan's own shared pages are reserved out of the eviction headroom
        — commit revives them, it must not also count them as reclaimable."""
        reserved = frozenset(shared.values())
        return (nblocks - len(shared)
                <= len(self.free) + self._evictable(reserved))

    # -- raw page ops --------------------------------------------------------

    def alloc(self, *, owner: int | None = None,
              tenant: str = DEFAULT_TENANT) -> int | None:
        if not self.free and self.cached:
            # Eviction runs ONLY here: when the alternative is returning
            # None (and the engine preempting live work).  Cold cache goes
            # before hot requests — docs/ROBUSTNESS.md §Eviction ordering.
            self._evict_one()
        if not self.free:
            return None
        page = self.free.pop()
        if self.refcount[page] != 0:
            raise AllocatorInvariantError(
                "free-list page has live refcount "
                f"{int(self.refcount[page])}", page=page,
                owner=self.page_owner.get(page),
            )
        self.refcount[page] = 1
        self.written.discard(page)  # recycled page: stale marker dies here
        self._tenant_refs[page] = {tenant: 1}
        if self._quantized:
            self.scale_live.add(page)
        if owner is not None:
            self.page_owner[page] = owner
        self.stats["allocs"] += 1
        self.stats["peak_in_use"] = max(self.stats["peak_in_use"], self.in_use())
        return page

    def share(self, page: int, *, owner: int | None = None,
              tenant: str = DEFAULT_TENANT) -> int:
        if self.refcount[page] <= 0:
            if page in self.cached:
                return self._revive(page, owner=owner, tenant=tenant)
            raise AllocatorInvariantError(
                "sharing unreferenced page", page=page,
                owner=self.page_owner.get(page),
            )
        if self._quantized and page not in self.scale_live:
            raise AllocatorInvariantError(
                "sharing a page without live scale state", page=page,
                owner=self.page_owner.get(page),
            )
        self.refcount[page] += 1
        refs = self._tenant_refs.setdefault(page, {})
        refs[tenant] = refs.get(tenant, 0) + 1
        self.stats["shared_hits"] += 1
        self._touch(page)
        if owner is not None:
            self.page_owner.setdefault(page, owner)
        return page

    def _revive(self, page: int, *, owner: int | None, tenant: str) -> int:
        """Cache hit on a parked rc==0 page: cached -> referenced.  Counted
        as BOTH an alloc and a shared hit — every rc 0->1 transition is an
        alloc and every 1->0 a free, so allocs == frees stays an exact
        conservation law whether or not pages detour through the cache."""
        if self._quantized and page not in self.scale_live:
            raise AllocatorInvariantError(
                "reviving a cached page without live scale state", page=page,
                owner=self.page_owner.get(page),
            )
        self.cached.remove(page)
        self.stats["cached_pages"] -= 1
        self.refcount[page] = 1
        self._tenant_refs[page] = {tenant: 1}
        if owner is not None:
            self.page_owner[page] = owner
        self.stats["allocs"] += 1
        self.stats["shared_hits"] += 1
        self.stats["peak_in_use"] = max(self.stats["peak_in_use"], self.in_use())
        self._touch(page)
        return page

    def free_page(self, page: int, *, owner: int | None = None,
                  tenant: str = DEFAULT_TENANT) -> None:
        if page == SCRATCH_PAGE:
            return
        if self.refcount[page] <= 0:
            # Double free / refcount underflow: typed, with the page id and
            # the slot that last owned it — the leak report the chaos harness
            # (docs/ROBUSTNESS.md) pins failures on.
            raise AllocatorInvariantError(
                "double free (refcount underflow)", page=page,
                owner=owner if owner is not None else self.page_owner.get(page),
            )
        self.refcount[page] -= 1
        refs = self._tenant_refs.get(page)
        if refs:
            t = tenant if refs.get(tenant, 0) > 0 else max(refs, key=refs.get)
            refs[t] -= 1
            if refs[t] <= 0:
                del refs[t]
        if self.refcount[page] != 0:
            return
        self._tenant_refs.pop(page, None)
        self.stats["frees"] += 1
        node = self.node_of_page.get(page)
        if node is not None and self.prefix_cache and page in self.written:
            # Retain: immutable content already landed — park in the tree at
            # rc==0 for future LCP hits instead of freeing.  scale_live is
            # intentionally KEPT (revival dequantizes through these scales).
            self.cached.add(page)
            self.stats["cached_pages"] += 1
            node.tenant = tenant
            self.page_owner.pop(page, None)
            self._touch(page)
            return
        if node is not None:
            # Registered but not retainable (unwritten in-flight block from
            # a rolled-back commit, cancelled chunked prefill, or cache off):
            # the node AND its subtree leave the tree — a dangling child
            # chain would advertise content reachable through a dead prefix.
            self._unregister_subtree(node)
        self.page_owner.pop(page, None)
        self.scale_live.discard(page)
        self.written.discard(page)
        self.free.append(page)

    def free_pages(self, pages: list[int], *, owner: int | None = None,
                   tenant: str = DEFAULT_TENANT) -> None:
        for p in pages:
            self.free_page(p, owner=owner, tenant=tenant)

    def claim_owner(self, pages: list[int], owner: int) -> None:
        """Record which slot a plan's pages now serve (diagnostics for
        AllocatorInvariantError; shared pages keep their first owner)."""
        for p in pages:
            self.page_owner.setdefault(p, owner)

    def mark_written(self, pages: list[int]) -> None:
        """Engine callback after KV content lands (prefill scatter / chunked
        commit): these pages now hold reusable bytes.  Only written pages are
        retained at rc==0 or safely shared mid-prefill; alloc() clears the
        marker when a page recycles."""
        for p in pages:
            if p != SCRATCH_PAGE and self.refcount[p] > 0:
                self.written.add(p)

    def is_written(self, page: int) -> bool:
        return page in self.written

    def is_registered(self, page: int) -> bool:
        return page in self.node_of_page

    # -- radix-tree maintenance ----------------------------------------------

    def _touch(self, page: int) -> None:
        node = self.node_of_page.get(page)
        if node is not None:
            self._tick += 1
            node.last_use = self._tick

    def _detach(self, node: _RadixNode) -> None:
        parent = node.parent
        if parent is not None and parent.children.get(node.key) is node:
            del parent.children[node.key]
        node.parent = None

    def _unregister_subtree(self, node: _RadixNode) -> None:
        """Remove a node and its whole subtree from the tree.  Referenced
        descendants (rc>0) just lose their registration and carry on as
        private pages; cached rc==0 descendants return to the free list —
        an orphaned cached page would be allocated, unreferenced, and
        unreachable: a leak by construction."""
        self._detach(node)
        stack = [node]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            n.children.clear()
            p = n.page
            if p is None:
                continue
            if self.node_of_page.get(p) is n:
                del self.node_of_page[p]
            if p in self.cached:
                self.cached.remove(p)
                self.stats["cached_pages"] -= 1
                self.scale_live.discard(p)
                self.page_owner.pop(p, None)
                self.written.discard(p)
                self.free.append(p)

    def _over_quota_tenants(self) -> set[str]:
        if self.tenant_quota is None:
            return set()
        return {t for t, u in self.tenant_footprint().items()
                if u > self.tenant_quota}

    def _evict_one(self) -> bool:
        """Evict the coldest evictable cached page: only rc==0 tree LEAVES
        are candidates (never a refcount>0 page, never a chain interior), so
        cold chains unwind tip-first and live chains are untouchable.
        Tenants over their page quota lose their cold leaves first."""
        over = self._over_quota_tenants()
        best: tuple[tuple, _RadixNode] | None = None
        for p in self.cached:
            node = self.node_of_page[p]
            if node.children:
                continue
            rank = (0 if node.tenant in over else 1, node.last_use, p)
            if best is None or rank < best[0]:
                best = (rank, node)
        if best is None:
            return False
        self._unregister_subtree(best[1])
        self.stats["evictions"] += 1
        return True

    # -- tenant accounting ---------------------------------------------------

    def tenant_usage(self) -> dict[str, float]:
        """Charged LIVE usage per tenant: a private page charges its tenant
        1, a shared page charges each reference 1/refcount — the charges sum
        to in_use() exactly, so quotas partition the pool."""
        usage: dict[str, float] = {}
        for p, refs in self._tenant_refs.items():
            rc = int(self.refcount[p])
            if rc <= 0:
                continue
            for t, n in refs.items():
                usage[t] = usage.get(t, 0.0) + n / rc
        return usage

    def tenant_footprint(self) -> dict[str, float]:
        """tenant_usage() plus parked cache pages, each charged in full to
        the tenant that released it last (rc==0: no sharing divisor).  This
        is the quantity eviction compares against the quota."""
        fp = self.tenant_usage()
        for p in self.cached:
            t = self.node_of_page[p].tenant or DEFAULT_TENANT
            fp[t] = fp.get(t, 0.0) + 1.0
        return fp

    # -- prompt planning (LCP reuse + copy-on-write) -------------------------

    def _block_key(self, prompt: np.ndarray, j: int) -> bytes:
        """Tree-edge key for block j: its block-local tokens (the chain of
        ancestor keys supplies the rest of the prefix identity)."""
        return np.ascontiguousarray(
            np.asarray(
                prompt[j * self.block_size:(j + 1) * self.block_size],
                np.int32,
            )
        ).tobytes()

    def shareable_blocks(self, prompt_len: int) -> int:
        """Blocks of this prompt that are immutable under decode (the engine's
        first decode step re-writes position prompt_len - 1)."""
        return max(0, (prompt_len - 1) // self.block_size)

    def plan_prompt(self, prompt: np.ndarray) -> tuple[int, dict[int, int]]:
        """(total blocks covering the prompt, {block j -> reusable page}).
        Walks the radix tree for the longest-common-prefix run of full
        blocks; the run ends at the first miss."""
        nblocks = self.blocks_for_tokens(len(prompt))
        shared: dict[int, int] = {}
        node = self.root
        for j in range(self.shareable_blocks(len(prompt))):
            child = node.children.get(self._block_key(prompt, j))
            if child is None:
                break
            shared[j] = child.page
            node = child
        return nblocks, shared

    def commit_prompt(
        self, prompt: np.ndarray, nblocks: int, shared: dict[int, int],
        *, tenant: str = DEFAULT_TENANT,
    ) -> PagePlan | None:
        """Materialize a plan: refcount (or revive) shared pages, allocate
        private ones, insert newly-allocated immutable blocks into the tree.
        Returns None (and rolls back) if the pool cannot cover the private
        blocks even after draining the evictable cache.

        Shared blocks are the LEADING run, so their shares (which revive any
        cached pages in the plan) always happen before the first alloc() —
        eviction inside alloc() can therefore never reclaim a page this very
        plan is about to reuse."""
        pages: list[int] = []
        is_shared: list[bool] = []
        immutable = self.shareable_blocks(len(prompt))
        cow_done = False
        node: _RadixNode | None = self.root
        for j in range(nblocks):
            if j in shared:
                pages.append(self.share(shared[j], tenant=tenant))
                is_shared.append(True)
                node = self.node_of_page.get(shared[j])
                continue
            page = self.alloc(tenant=tenant)
            if page is None:
                for p in pages:
                    self.free_page(p, tenant=tenant)
                return None
            if shared and not cow_done:
                # First private block after a shared prefix: the
                # copy-on-write point (divergent or appendable block).
                self.stats["cow_events"] += 1
                cow_done = True
            if j < immutable and node is not None:
                key = self._block_key(prompt, j)
                child = node.children.get(key)
                if child is None:
                    child = _RadixNode(key, page, node, tenant)
                    node.children[key] = child
                    self.node_of_page[page] = child
                    self._tick += 1
                    child.last_use = self._tick
                # else: an in-flight writer already owns this block key (the
                # engine declined its unwritten page and we recomputed a
                # private copy).  First writer wins — our copy stays
                # unregistered — and the walk continues down the existing
                # chain so deeper blocks still land in the right subtree.
                node = child
            elif j >= immutable:
                node = None
            pages.append(page)
            is_shared.append(False)
        self.stats["hit_blocks"] += len(shared)
        self.stats["hit_tokens"] += len(shared) * self.block_size
        self.stats["lookup_blocks"] += immutable
        return PagePlan(pages=pages, shared=is_shared)

    # -- invariants ----------------------------------------------------------

    def audit(self, tables_in_use: list[list[int]]) -> None:
        """Raises AssertionError unless the allocator state is exactly
        consistent with the referenced tables:

          * every referenced page is allocated, never on the free list and
            never simultaneously cached,
          * refcounts equal the number of table references exactly, and the
            per-tenant charge ledger sums to the refcount per page,
          * a page referenced by two tables is registered in the radix tree
            (sharing happens only through prefix reuse),
          * free / referenced / cached partitions the pool (scratch
            excluded): an rc==0 allocated page NOT parked in the tree is a
            leak by construction,
          * tree<->pool cross-invariants: every tree node's page is
            allocated (no tree ref to a freed page), an rc==0 page the tree
            reaches is in the cached set (no refcounted-0-but-allocated
            stragglers), each page sits at exactly ONE node (no cached page
            reachable by two keys), `node_of_page` and the root walk agree
            exactly, and every cached page carries the written marker,
          * under a quantized layout (kv8/kv4), scale state tracks the
            ALLOCATED set (referenced + cached) in lockstep: cached pages
            keep their scales for revival, freed pages must not."""
        refs: dict[int, int] = {}
        for table in tables_in_use:
            for p in table:
                assert p != SCRATCH_PAGE, "scratch page referenced as data"
                refs[p] = refs.get(p, 0) + 1
        free_set = set(self.free)
        assert len(free_set) == len(self.free), "duplicate pages on free list"
        for p, n in refs.items():
            assert p not in free_set, f"page {p} both referenced and free"
            assert p not in self.cached, f"page {p} both referenced and cached"
            assert self.refcount[p] == n, (
                f"page {p}: refcount {self.refcount[p]} != {n} references"
            )
            trefs = self._tenant_refs.get(p, {})
            assert sum(trefs.values()) == n, (
                f"page {p}: tenant charges {trefs} do not sum to {n}"
            )
            if n > 1:
                assert p in self.node_of_page, (
                    f"page {p} multiply-owned unregistered"
                )
        for p in range(1, self.num_pages):
            if p in refs:
                continue
            if p in self.cached:
                assert self.refcount[p] == 0, (
                    f"cached page {p} has refcount {self.refcount[p]}"
                )
                assert p not in free_set, f"page {p} both cached and free"
                assert p in self.node_of_page, f"cached page {p} not in tree"
                assert p in self.written, f"cached page {p} never written"
                continue
            if self.refcount[p] != 0:
                raise AllocatorInvariantError(
                    f"page leaked (rc={int(self.refcount[p])}, "
                    "unreferenced)", page=p, owner=self.page_owner.get(p),
                )
            assert p in free_set, f"page {p} neither free, referenced, nor cached"
        assert len(free_set) + len(refs) + len(self.cached) == self.capacity
        # Tree <-> pool cross-invariants, by exhaustive root walk.
        seen: set[int] = set()
        stack = [self.root]
        while stack:
            n = stack.pop()
            for key, c in n.children.items():
                assert c.parent is n and c.key == key, "tree link corrupt"
                p = c.page
                assert p is not None and p != SCRATCH_PAGE
                if p in free_set or self.refcount[p] < 0:
                    raise AllocatorInvariantError(
                        "prefix tree references a freed page", page=p,
                        owner=self.page_owner.get(p),
                    )
                if self.refcount[p] == 0 and p not in self.cached:
                    raise AllocatorInvariantError(
                        "tree reaches an rc==0 page outside the cached set",
                        page=p, owner=self.page_owner.get(p),
                    )
                assert p not in seen, (
                    f"page {p} reachable by two tree keys"
                )
                assert self.node_of_page.get(p) is c, (
                    f"node_of_page disagrees with tree for page {p}"
                )
                seen.add(p)
                stack.append(c)
        assert seen == set(self.node_of_page), (
            "node_of_page and root walk disagree "
            f"({sorted(set(self.node_of_page) - seen)} unreachable)"
        )
        if self._quantized:
            allocated = set(refs) | self.cached
            for p in allocated:
                if p not in self.scale_live:
                    raise AllocatorInvariantError(
                        "allocated page lacks live scale state", page=p,
                        owner=self.page_owner.get(p),
                    )
            for p in self.scale_live:
                if p not in allocated:
                    raise AllocatorInvariantError(
                        "freed page still holds scale state", page=p,
                        owner=self.page_owner.get(p),
                    )


class ShardedBlockAllocator:
    """Per-shard paged bookkeeping for tensor-parallel serving.

    Under head-parallel attention every shard holds ITS OWN head-slice of
    every KV page, so each shard owns a full per-shard pool and block table
    — but page IDENTITY must agree across shards (the block table threaded
    into the SPMD dispatch is one logical table; shard k's gather of page p
    must read shard k's slice of the same request's history).  This class
    drives one `BlockAllocator` per shard in lockstep: every operation
    (alloc, share, free, prompt plan/commit, written markers, tenant
    charges, cache eviction — eviction is deterministic, it runs inside each
    shard's alloc()) is applied to all shards and the results are asserted
    identical.  BlockAllocator is deterministic by construction (LIFO free
    list, exact refcounts, radix-tree walk order fixed by insertion, LRU
    ranks totally ordered by (quota class, tick, page id)), so mirrored
    shards can only diverge through a bookkeeping bug — which this class
    converts into an `AllocatorInvariantError` naming the shard, instead of
    silent cross-shard KV corruption.

    COW, preemption, eviction, and `audit()` therefore stay SHARD-LOCAL:
    each shard's allocator proves its own exact partition (per-shard audit
    is what tests/test_tp_mesh.py pins after preemption/replay), while the
    engine keeps exactly one host block table.  The interface mirrors
    BlockAllocator, so Engine code is allocator-agnostic."""

    def __init__(self, num_pages: int, block_size: int, *, shards: int,
                 kv_quant: str = "bf16", prefix_cache: bool = True,
                 tenant_quota: int | None = None):
        assert shards >= 1, shards
        self.shards = [
            BlockAllocator(num_pages, block_size, kv_quant,
                           prefix_cache=prefix_cache,
                           tenant_quota=tenant_quota)
            for _ in range(shards)
        ]
        self.num_pages = num_pages
        self.block_size = block_size
        self.kv_quant = kv_quant
        self.prefix_cache = prefix_cache
        self.tenant_quota = tenant_quota

    @property
    def _p(self) -> BlockAllocator:
        return self.shards[0]

    def _mirror(self, results, what: str):
        first = results[0]
        for k, r in enumerate(results[1:], start=1):
            if r != first:
                raise AllocatorInvariantError(
                    f"shard allocators diverged on {what}: shard 0 -> "
                    f"{first!r}, shard {k} -> {r!r}"
                )
        return first

    # -- capacity (identical across shards by construction) ------------------

    @property
    def capacity(self) -> int:
        return self._p.capacity

    def available(self) -> int:
        return self._mirror([a.available() for a in self.shards], "available")

    def in_use(self) -> int:
        return self._p.in_use()

    def blocks_for_tokens(self, tokens: int) -> int:
        return self._p.blocks_for_tokens(tokens)

    def shareable_blocks(self, prompt_len: int) -> int:
        return self._p.shareable_blocks(prompt_len)

    def plan_fits(self, nblocks: int, shared: dict[int, int]) -> bool:
        return self._mirror(
            [a.plan_fits(nblocks, shared) for a in self.shards], "plan_fits"
        )

    # -- mirrored page ops ----------------------------------------------------

    def alloc(self, *, owner: int | None = None,
              tenant: str = DEFAULT_TENANT) -> int | None:
        return self._mirror(
            [a.alloc(owner=owner, tenant=tenant) for a in self.shards],
            "alloc",
        )

    def share(self, page: int, *, owner: int | None = None,
              tenant: str = DEFAULT_TENANT) -> int:
        return self._mirror(
            [a.share(page, owner=owner, tenant=tenant) for a in self.shards],
            "share",
        )

    def free_page(self, page: int, *, owner: int | None = None,
                  tenant: str = DEFAULT_TENANT) -> None:
        for a in self.shards:
            a.free_page(page, owner=owner, tenant=tenant)

    def free_pages(self, pages: list[int], *, owner: int | None = None,
                   tenant: str = DEFAULT_TENANT) -> None:
        for a in self.shards:
            a.free_pages(pages, owner=owner, tenant=tenant)

    def claim_owner(self, pages: list[int], owner: int) -> None:
        for a in self.shards:
            a.claim_owner(pages, owner)

    def mark_written(self, pages: list[int]) -> None:
        for a in self.shards:
            a.mark_written(pages)

    def is_written(self, page: int) -> bool:
        return self._mirror(
            [a.is_written(page) for a in self.shards], "is_written"
        )

    def is_registered(self, page: int) -> bool:
        return self._mirror(
            [a.is_registered(page) for a in self.shards], "is_registered"
        )

    # -- mirrored prompt planning ---------------------------------------------

    def plan_prompt(self, prompt: np.ndarray) -> tuple[int, dict[int, int]]:
        return self._mirror(
            [a.plan_prompt(prompt) for a in self.shards], "plan_prompt"
        )

    def commit_prompt(
        self, prompt: np.ndarray, nblocks: int, shared: dict[int, int],
        *, tenant: str = DEFAULT_TENANT,
    ) -> PagePlan | None:
        plans = [a.commit_prompt(prompt, nblocks, shared, tenant=tenant)
                 for a in self.shards]
        self._mirror(
            [(p.pages, p.shared) if p is not None else None for p in plans],
            "commit_prompt",
        )
        return plans[0]

    # -- mirrored tenant accounting -------------------------------------------

    def tenant_usage(self) -> dict[str, float]:
        return self._mirror(
            [a.tenant_usage() for a in self.shards], "tenant_usage"
        )

    def tenant_footprint(self) -> dict[str, float]:
        return self._mirror(
            [a.tenant_footprint() for a in self.shards], "tenant_footprint"
        )

    # -- observability / invariants -------------------------------------------

    @property
    def stats(self) -> dict:
        """Shard-0 counters (mirrors are identical — asserted on every
        mutating op) plus the shard count, so engine stats stay one dict."""
        return {**self._p.stats, "tp_shards": len(self.shards)}

    def per_shard_stats(self) -> list[dict]:
        return [dict(a.stats) for a in self.shards]

    def audit(self, tables_in_use: list[list[int]]) -> None:
        """Run the exact-partition audit on EVERY shard's allocator: each
        shard must independently account for the same referenced tables."""
        for k, a in enumerate(self.shards):
            try:
                a.audit(tables_in_use)
            except AssertionError as exc:
                raise AllocatorInvariantError(
                    f"shard {k} audit failed: {exc}"
                ) from exc
