"""Model-free prompt-lookup drafting for speculative decode (serving/engine.py).

V-Seek-style speculation without a separate draft model: the draft for a
slot's next `k` tokens is read out of the request's OWN token history
(prompt + generated so far).  If the trailing n-gram (the last `ngram`
tokens, falling back to shorter suffixes down to `min_ngram`) occurred
earlier in the history, the tokens that followed its most recent earlier
occurrence are proposed verbatim.

On repetition-heavy workloads (code completion, extraction, templated chat,
greedy loops) acceptance is high; on incompressible text the drafter simply
proposes nothing and the engine falls back to plain one-token decode — a
proposal costs no model dispatch either way (pure host-side numpy, never
traced).  Correctness never depends on draft quality: the verify step commits
a draft token only when it equals the model's own greedy choice, so engine
output is token-identical to plain greedy decode for ANY drafter (the
token-identity harness in tests/test_spec_decode.py pins this with both this
drafter and an adversarial one).

Interaction with the paged prefix cache: rejected draft tokens roll the
slot's position back, and the engine then returns the pages past the new
block high-water mark to the allocator (`Engine._truncate_slot_pages`).
That rollback path must only ever hand back PRIVATE, unregistered pages —
a page registered in the radix prefix tree holds immutable, fully-written
prompt KV by construction (only whole prompt blocks are ever registered,
and speculation never rolls back into the prompt), so rollback freeing a
tree-cached page would corrupt every future request that hits that prefix.
`_truncate_slot_pages` asserts this contract; the allocator's audit()
cross-checks it after every chaos/property storm.
"""

from __future__ import annotations

import numpy as np

_EMPTY = np.zeros((0,), np.int32)


def propose(
    context: np.ndarray,
    k: int,
    *,
    ngram: int = 3,
    min_ngram: int = 1,
) -> np.ndarray:
    """Up to `k` draft tokens continuing `context` by prompt lookup.

    Matches the longest trailing n-gram (length `ngram` down to `min_ngram`)
    against every earlier position of `context`; on a hit, returns the tokens
    that followed the most recent earlier occurrence that still has a full
    k-token continuation (recency wins — the local pattern beats a stale one
    — but a match flush against the end of the context has nothing left to
    propose, so matches too close to the end defer to the longest available
    continuation: on a periodic tail this is what keeps drafts k tokens
    long).  Returns an empty array when no suffix recurs or there is nothing
    usable to propose.
    """
    ctx = np.asarray(context, np.int32).ravel()
    n_ctx = int(ctx.shape[0])
    if k <= 0 or n_ctx < min_ngram + 1:
        return _EMPTY
    for n in range(min(ngram, n_ctx - 1), min_ngram - 1, -1):
        suffix = ctx[n_ctx - n:]
        windows = np.lib.stride_tricks.sliding_window_view(ctx, n)
        hits = np.flatnonzero((windows == suffix).all(axis=1))
        # Earlier occurrences only, with at least one token following them.
        hits = hits[hits + n < n_ctx]
        if hits.size:
            room = n_ctx - (hits + n)  # continuation tokens after each match
            full = hits[room >= k]
            start = int(full[-1] if full.size else hits[np.argmax(room)]) + n
            return np.ascontiguousarray(ctx[start : start + k], dtype=np.int32)
    return _EMPTY


def draft_budget(draft_k: int, decode_rows: int, token_budget: int | None) -> int:
    """Per-slot draft cap under a token budget (the token-budget mixed step,
    serving/engine.py): spec-verify windows spend the SAME budget as every
    other token in the dispatch, so with `decode_rows` slots decoding, each
    may draft at most

        floor((budget - decode_rows) / decode_rows)

    tokens — the decode rows' own 1-token-per-slot floor is reserved first
    (decode never stalls for drafts), and what remains splits evenly.  The
    result is clamped to [0, draft_k]; with no budget (phase-split engines)
    the full draft_k stands.  Chunked-prefill rows then take what the drafts
    left over, so speculation and prefill compete for one pool instead of
    speculation silently inflating the dispatch past the budget."""
    if token_budget is None or decode_rows <= 0:
        return max(0, int(draft_k))
    spare = (int(token_budget) - decode_rows) // decode_rows
    return max(0, min(int(draft_k), spare))
