"""Serving: phase-split prefill/decode steps (the paper's two regimes) and a
continuous-batching engine.

`make_prefill_step` / `make_decode_step` build the jit-able functions the
dry-run lowers (`serve_step` == one decode token against a seq_len KV cache).
The `Engine` drives them for real batched requests (examples/serve_llama.py):
slot-based continuous batching — new requests prefill into free slots while
existing slots keep decoding.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.models import transformer as T


def make_prefill_step(cfg, enc: EncodingConfig) -> Callable:
    def prefill(params, tokens, caches, extras=None):
        batch = {"tokens": tokens, **(extras or {})}
        # Serving prefill only needs the final position's logits (the first
        # sampled token); (B, S, V) is never materialized.
        logits, caches, _ = T.forward(
            params, batch, cfg=cfg, enc=enc, phase=Phase.PREFILL, caches=caches,
            last_logits_only=True,
        )
        return logits, caches

    return prefill


def make_chunked_prefill_step(cfg, enc: EncodingConfig, *, chunk: int = 512) -> Callable:
    """Prefill long prompts in fixed chunks (bounded activation memory, the
    standard long-prompt serving pattern).  Each chunk runs as a PREFILL with
    `pos` offset; caches accumulate exactly as a single-shot prefill would.

    Returns prefill_chunked(params, tokens, caches) -> (last_logits, caches).
    Requires full attention or window <= chunk handling via the dense cache
    (positions are absolute)."""

    def one_chunk(params, tokens, caches, pos):
        logits, caches, _ = T.forward(
            params, {"tokens": tokens}, cfg=cfg, enc=enc, phase=Phase.PREFILL,
            caches=caches, pos=pos, last_logits_only=True,
        )
        return logits, caches

    def prefill_chunked(params, tokens, caches):
        b, s = tokens.shape
        logits = None
        for lo in range(0, s, chunk):
            hi = min(s, lo + chunk)
            logits, caches = one_chunk(params, tokens[:, lo:hi], caches, lo)
        return logits, caches

    return prefill_chunked


def make_decode_step(cfg, enc: EncodingConfig, *, sample: str = "greedy") -> Callable:
    def decode(params, caches, token, pos):
        """token: (B, 1) int32; pos: () int32 — position of `token`."""
        logits, caches, _ = T.forward(
            params,
            {"tokens": token},
            cfg=cfg,
            enc=enc,
            phase=Phase.DECODE,
            caches=caches,
            pos=pos,
        )
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, caches

    return decode


def _batch_axis(path) -> int:
    """Cache leaves under "groups" carry a leading layer-stack dim: batch is
    axis 1 there, axis 0 in the tail."""
    first = path[0]
    name = getattr(first, "key", getattr(first, "idx", ""))
    return 1 if str(name) == "groups" else 0


def slot_slice(caches, s: int):
    def one(path, c):
        ax = _batch_axis(path)
        return jax.lax.slice_in_dim(c, s, s + 1, axis=ax)

    return jax.tree_util.tree_map_with_path(one, caches)


def slot_merge(caches, part, slots_sel: list[int], src_idx: list[int] | None = None):
    """Write batch rows `src_idx` (default: same as slots_sel) of `part` into
    rows `slots_sel` of `caches`."""
    src_idx = src_idx if src_idx is not None else slots_sel

    def one(path, full, p):
        ax = _batch_axis(path)
        for dst, src in zip(slots_sel, src_idx):
            row = jax.lax.slice_in_dim(p, src, src + 1, axis=ax)
            idx = [slice(None)] * full.ndim
            idx[ax] = slice(dst, dst + 1)
            full = full.at[tuple(idx)].set(row)
        return full

    return jax.tree_util.tree_map_with_path(one, caches, part)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray        # (S,) int32
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Slot-based continuous batching on a fixed decode batch."""

    def __init__(self, params, cfg, enc: EncodingConfig, *, slots: int = 4, max_seq: int = 256):
        self.params, self.cfg, self.enc = params, cfg, enc
        self.slots = slots
        self.max_seq = max_seq
        self.prefill_fn = jax.jit(make_prefill_step(cfg, enc))
        self.decode_fn = jax.jit(make_decode_step(cfg, enc))
        self.caches = T.cache_init(cfg, slots, max_seq)
        self.slot_req: list[Request | None] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                # Per-slot prefill: batch of 1 through a slot-sliced cache view.
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                slot_cache = slot_slice(self.caches, s)
                _, slot_cache = self.prefill_fn(self.params, toks, slot_cache)
                self.caches = slot_merge(self.caches, slot_cache, [s], [0])
                self.slot_req[s] = req
                self.slot_pos[s] = len(req.prompt)

    def step(self) -> int:
        """One engine iteration: admit + one decode for every active slot."""
        self._admit()
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        last_tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            last = req.generated[-1] if req.generated else int(req.prompt[-1])
            last_tokens[s, 0] = last
        # Slots admitted with different prompt lengths decode on their own pos
        # via per-pos grouping; each group's cache rows merge back selectively
        # so other groups' histories stay untouched.
        groups: dict[int, list[int]] = {}
        for s in active:
            groups.setdefault(int(self.slot_pos[s]), []).append(s)
        emitted = 0
        for p, slots in groups.items():
            nxt, _, new_caches = self.decode_fn(
                self.params, self.caches, jnp.asarray(last_tokens), jnp.asarray(p - 1, jnp.int32)
            )
            self.caches = slot_merge(self.caches, new_caches, slots)
            for s in slots:
                req = self.slot_req[s]
                tok = int(np.asarray(nxt)[s, 0])
                req.generated.append(tok)
                self.slot_pos[s] += 1
                emitted += 1
                if len(req.generated) >= req.max_new_tokens or self.slot_pos[s] >= self.max_seq:
                    req.done = True
                    self.finished.append(req)
                    self.slot_req[s] = None
        return emitted

    def run(self) -> list[Request]:
        while self.queue or any(r is not None for r in self.slot_req):
            self.step()
        return self.finished
