"""Serving: phase-split prefill/decode steps (the paper's two regimes) and a
continuous-batching engine.

`make_prefill_step` / `make_decode_step` build the jit-able functions the
dry-run lowers (`serve_step` == one decode token against a seq_len KV cache).
The `Engine` drives them for real batched requests (examples/serve_llama.py):
slot-based continuous batching — new requests prefill into free slots while
existing slots keep decoding.

Decode fast path (decode_mode="vectorized", the default): every active slot
decodes in ONE jitted call per engine step regardless of prompt-length skew —
`pos` is a per-slot vector threaded through the model's cache indexing, the
step donates the cache buffers (in-place update, no copy), and the returned
caches replace the engine's wholesale (no per-slot merge scatter).  The
pre-existing per-position-group dispatch loop is kept as
decode_mode="grouped" — it is the baseline the vectorized path is benchmarked
against (benchmarks/table2_throughput.py, BENCH_decode.json).

Paged KV cache (cache_mode="paged", the default for attention-only models):
KV memory is a global pool of fixed-size pages plus a per-slot block table
(serving/paged.py owns the host-side allocator; models/layers.py gathers
pages by table inside the decode dispatch).  Admission charges only the
blocks a prompt actually needs, shared prompt prefixes map to the same
physical pages (copy-on-write at the first divergent block), and decode
growth preempts the lowest-priority slot (latest admission ticket — its
request requeues and replays) when the pool is exhausted.  cache_mode="dense"
keeps the PR-1 worst-case (slots, max_seq) reservation as the parity
baseline; recurrent families (rec/rwkv) and sliding-window configs are
auto-routed to it.

Speculative decode (spec_decode=True): a model-free prompt-lookup drafter
(serving/spec.py) proposes up to draft_k tokens per slot per step; ONE
batched multi-token verify dispatch (make_verify_step — a decode-phase
forward over (B, L) tokens with per-row position vectors, masked-causal
inside the draft window, writing L cache positions per row) scores them; the
engine commits each slot's longest greedy-consistent draft prefix plus the
model's own next token, and rolls rejected tokens back (dense: masked until
overwritten; paged: trailing pages freed — audit() stays exact).  Output is
token-identical to plain greedy decode for any drafter; acceptance only buys
dispatch amortization (docs/PERF.md §Speculative decode).

Hardened request lifecycle (docs/ROBUSTNESS.md): every request carries a
status (queued -> running -> ok | cancelled | expired | error | rejected).
`submit` is backpressured — a bounded admission queue and up-front
serviceability checks return a structured `Rejected(reason)` instead of
admitting work that can only thrash — and step boundaries honour
`Request.cancel()` and per-request `deadline_ms` (pages freed through the
same `_finish_slot` path as normal completion, so `audit()` stays exact).
Committed logits pass a non-finite guard: a NaN/inf row quarantines only the
offending slot (finish-with-error; co-batched rows commit normally), and a
dispatch that raises demotes its registry key down the requested -> tuned ->
policy -> fallback ladder (kernels/registry.demote) for the rest of the
process, recorded in stats["degraded"].  A DecodeStepWatchdog
(runtime/watchdog.py) brackets every step: EWMA step latency, stall flags,
p50/p99 — surfaced in stats["watchdog"].  All fault paths are driven through
injectable hooks (`fault_hooks`, `clock`) so the chaos layer
(serving/faults.py) needs no monkeypatching.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding as encoding_lib
from repro.core.encoding import Phase
from repro.core.packed import EncodingConfig
from repro.kernels import registry as registry_lib
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.parallel import sharding as sharding_lib
from repro.runtime import watchdog as watchdog_lib
from repro.serving import faults as faults_lib
from repro.serving import paged as paged_lib
from repro.serving import spec as spec_lib
from repro.serving.config import EngineConfig


def make_prefill_step(cfg, enc: EncodingConfig) -> Callable:
    def prefill(params, tokens, caches, extras=None):
        batch = {"tokens": tokens, **(extras or {})}
        # Serving prefill only needs the final position's logits (the first
        # sampled token); (B, S, V) is never materialized.
        logits, caches, _ = T.forward(
            params, batch, cfg=cfg, enc=enc, phase=Phase.PREFILL, caches=caches,
            last_logits_only=True,
        )
        return logits, caches

    return prefill


def make_suffix_prefill_step(cfg, enc: EncodingConfig) -> Callable:
    """Prefill ONLY the un-cached suffix of a prompt whose leading blocks
    were served by the radix prefix cache: the cached K/V is gathered into
    the temp dense cache first (engine._gather_prefix), then this step runs
    a PREFILL at static offset `pos` — the same prior-concat path chunked
    prefill uses, so suffix keys attend the gathered prefix exactly as a
    full prefill would.  `pos` must be a static int (jit static_argnums):
    the attention slice `cache[:, :pos]` needs a compile-time length."""

    def suffix_prefill(params, tokens, caches, pos):
        logits, caches, _ = T.forward(
            params, {"tokens": tokens}, cfg=cfg, enc=enc, phase=Phase.PREFILL,
            caches=caches, pos=pos, last_logits_only=True,
        )
        return logits, caches

    return suffix_prefill


def make_chunked_prefill_step(cfg, enc: EncodingConfig, *, chunk: int = 512) -> Callable:
    """Prefill long prompts in fixed chunks (bounded activation memory, the
    standard long-prompt serving pattern).  Each chunk runs as a PREFILL with
    `pos` offset; caches accumulate exactly as a single-shot prefill would.

    Returns prefill_chunked(params, tokens, caches) -> (last_logits, caches).
    Requires full attention or window <= chunk handling via the dense cache
    (positions are absolute)."""
    if 0 < chunk < cfg.sliding_window:
        # A window wider than the chunk needs keys from earlier chunks that
        # the windowed prefill path never concatenates back in — the result
        # would be silently wrong, not slow.
        raise ValueError(
            f"chunked prefill requires sliding_window <= chunk: window "
            f"{cfg.sliding_window} > chunk {chunk} would silently drop "
            "cross-chunk attention (grow chunk, or prefill single-shot)"
        )

    def one_chunk(params, tokens, caches, pos):
        logits, caches, _ = T.forward(
            params, {"tokens": tokens}, cfg=cfg, enc=enc, phase=Phase.PREFILL,
            caches=caches, pos=pos, last_logits_only=True,
        )
        return logits, caches

    def prefill_chunked(params, tokens, caches):
        b, s = tokens.shape
        logits = None
        for lo in range(0, s, chunk):
            hi = min(s, lo + chunk)
            logits, caches = one_chunk(params, tokens[:, lo:hi], caches, lo)
        return logits, caches

    return prefill_chunked


SAMPLE_MODES = ("greedy", "temperature")


def make_decode_step(cfg, enc: EncodingConfig, *, sample: str = "greedy") -> Callable:
    """One-token decode step.

    sample="greedy"      -> decode(params, caches, token, pos): argmax.
    sample="temperature" -> decode(params, caches, token, pos, key, temp):
        per-row temperature sampling — `temp` is (B,) float32, `key` a PRNG
        key for THIS step (the engine folds a step counter into its base
        key).  Rows with temp <= 0 take the argmax (per-slot greedy inside a
        sampled batch).
    """
    if sample not in SAMPLE_MODES:
        raise ValueError(f"sample must be one of {SAMPLE_MODES}, got {sample!r}")

    def _forward(params, caches, token, pos):
        logits, caches, _ = T.forward(
            params,
            {"tokens": token},
            cfg=cfg,
            enc=enc,
            phase=Phase.DECODE,
            caches=caches,
            pos=pos,
        )
        return logits, caches

    if sample == "greedy":

        def decode(params, caches, token, pos):
            """token: (B, 1) int32; pos: () or (B,) int32 — position of
            `token` (per-row when vectorized over slot positions)."""
            logits, caches = _forward(params, caches, token, pos)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return nxt[:, None], logits, caches

        return decode

    def decode_sampled(params, caches, token, pos, key, temp):
        logits, caches = _forward(params, caches, token, pos)
        last = logits[:, -1, :].astype(jnp.float32)
        greedy = jnp.argmax(last, axis=-1).astype(jnp.int32)
        scaled = last / jnp.maximum(temp, 1e-6)[:, None]
        sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
        nxt = jnp.where(temp > 0, sampled, greedy)
        return nxt[:, None], logits, caches

    return decode_sampled


def make_verify_step(cfg, enc: EncodingConfig) -> Callable:
    """Batched multi-token verify for speculative decode.

    verify(params, caches, tokens, pos) -> (logits, caches), where tokens is
    (B, L) int32 — row b's last committed token followed by its L-1 draft
    tokens — and pos is (B,) int32, the position of tokens[:, 0].  One
    decode-phase forward scores the whole draft window: the model's cache
    indexing writes all L positions per row and the decode mask is
    masked-causal within the window (models/layers.py attention_decode), so
    logits[:, j] is the next-token distribution given the committed history
    plus drafts 0..j — exactly what greedy acceptance compares against.
    """

    def verify(params, caches, tokens, pos):
        logits, caches, _ = T.forward(
            params,
            {"tokens": tokens},
            cfg=cfg,
            enc=enc,
            phase=Phase.DECODE,
            caches=caches,
            pos=pos,
        )
        return logits, caches

    return verify


def make_mixed_step(cfg, enc: EncodingConfig) -> Callable:
    """Token-budget mixed step: chunked prefill and decode in ONE dispatch.

    mixed(params, caches, tokens, pos, logits_idx) -> (logits, caches).
    tokens is (B, L) int32 — row b's window is EITHER its last committed
    token plus draft tokens (a decoding slot; exactly make_verify_step's
    contract) OR the next chunk of its prompt (a prefilling slot) — and pos
    is (B,) int32, the absolute position of tokens[:, 0].  Both row kinds
    want the same decode-phase forward: the per-row multi-position cache
    scatter and the masked-causal window mask `slot <= pos_b + j` ARE
    chunked prefill when the window holds prompt tokens (models/layers.py
    attention_apply documents the contract).  logits_idx is (B, K) int32:
    per-row window indices whose hidden states are gathered BEFORE the
    output head (models/transformer.py), so a chunk row pays for K logit
    rows, never L — a 4k-token prompt chunk costs no (chunk, vocab) logits.
    """

    def mixed(params, caches, tokens, pos, logits_idx):
        logits, caches, _ = T.forward(
            params,
            {"tokens": tokens},
            cfg=cfg,
            enc=enc,
            phase=Phase.DECODE,
            caches=caches,
            pos=pos,
            logits_idx=logits_idx,
        )
        return logits, caches

    return mixed


def _batch_axis(path) -> int:
    """Cache leaves under "groups" carry a leading layer-stack dim: batch is
    axis 1 there, axis 0 in the tail."""
    first = path[0]
    name = getattr(first, "key", getattr(first, "idx", ""))
    return 1 if str(name) == "groups" else 0


def slot_gather(caches, slots_sel: list[int]):
    """Batch rows `slots_sel` of every cache leaf, as one gather per leaf."""
    # Host-side index build (np, not jnp): these gathers run eagerly on
    # possibly-sharded cache leaves, and a committed device index array would
    # pin the op to the default device and clash with NamedSharding inputs.
    idx = np.asarray(slots_sel, np.int32)

    def one(path, c):
        return jnp.take(c, idx, axis=_batch_axis(path))

    return jax.tree_util.tree_map_with_path(one, caches)


def slot_slice(caches, s: int):
    return slot_gather(caches, [s])


def slot_merge(caches, part, slots_sel: list[int], src_idx: list[int] | None = None):
    """Write batch rows `src_idx` (default: same as slots_sel) of `part` into
    rows `slots_sel` of `caches` — one gather + one scatter per leaf (the
    per-slot .at[].set loop scaled O(slots) dispatches per leaf)."""
    src = np.asarray(src_idx if src_idx is not None else slots_sel, np.int32)
    dst = np.asarray(slots_sel, np.int32)

    def one(path, full, p):
        ax = _batch_axis(path)
        rows = jnp.take(p, src, axis=ax)
        if ax == 0:
            return full.at[dst].set(rows)
        return full.at[:, dst].set(rows)

    return jax.tree_util.tree_map_with_path(one, caches, part)


def count_calls(fn):
    """Wrap `fn` with a dispatch counter (`fn.calls`) — instrumentation for
    the decode-dispatch invariants (benchmarks and tests)."""

    def wrapped(*args, **kwargs):
        wrapped.calls += 1
        return fn(*args, **kwargs)

    wrapped.calls = 0
    return wrapped


REQUEST_STATUSES = (
    "queued", "running", "ok", "cancelled", "expired", "error", "rejected",
)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray        # (S,) int32
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Decode finishes the slot early when this token is emitted (the EOS
    # itself is kept in `generated`; nothing past it is ever emitted).
    eos_id: int | None = None
    # Per-slot sampling temperature (engines built with sample="temperature"
    # only; <= 0 means greedy for this request inside a sampled batch).
    temperature: float = 1.0
    # Speculative-decode accounting (filled by the engine when spec decode
    # served this request): drafts offered / drafts accepted.
    draft_proposed: int = 0
    draft_accepted: int = 0
    # ---- lifecycle (docs/ROBUSTNESS.md) ------------------------------------
    # Wall-clock budget from submit() to last token, in ms of the ENGINE's
    # clock (injectable).  None = no deadline.  Checked at step boundaries:
    # an expired request finishes with status "expired", keeping whatever it
    # generated so far.
    deadline_ms: float | None = None
    status: str = "queued"
    error: str | None = None
    cancel_requested: bool = False
    submit_t: float | None = None     # engine clock at submit()
    # SLO class for the token-budget scheduler ("interactive" | "standard" |
    # "batch"; unknown values rank as "standard").  Queue ordering ages by
    # enqueued_step (stamped by submit()) so no class starves.
    slo_class: str = "standard"
    enqueued_step: int | None = None
    # Tenant for per-tenant page-quota accounting (paged engines with
    # EngineConfig.tenant_quota set): admission reserves this request's
    # worst-case page footprint against its tenant's quota, so one tenant's
    # long-context jobs cannot starve the pool for everyone else.
    tenant: str = "default"

    def cancel(self) -> None:
        """Ask the engine to drop this request.  Honoured at the next step
        boundary (and again at commit time, so a cancel landing while a
        draft window is in flight never emits another token)."""
        self.cancel_requested = True


@dataclasses.dataclass(frozen=True)
class Admitted:
    """submit() accepted the request into the admission queue."""

    uid: int

    def __bool__(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class Rejected:
    """submit() refused the request — structured backpressure, never an
    unbounded queue.  `reason` is machine-readable ("queue_full" |
    "unserviceable_seq" | "unserviceable_pool" | "unserviceable_quota");
    `detail` is for humans."""

    uid: int
    reason: str
    detail: str = ""

    def __bool__(self) -> bool:
        return False


# Lower rank = more urgent.  Unknown classes rank as "standard".
SLO_CLASSES = {"interactive": 0, "standard": 1, "batch": 2}


class TokenBudgetScheduler:
    """Admission / budget-split / preemption policy for the token-budget
    mixed step (`Engine(token_budget=...)`; docs/PERF.md §Token budget).

    Admission order: SLO class rank (interactive < standard < batch) with
    starvation-free aging — every `aging_steps` engine steps a request
    spends queued promote it one class, so a batch request enqueued long
    enough eventually outranks a steady stream of fresh interactive ones.
    Ties break FIFO (enqueued_step, then submission order).

    Budget split per step: decode rows are funded first (1 token per row —
    the zero-stall floor), then spec drafts (spec.draft_budget), and
    chunked prefill takes what remains — never less than 1 token per
    prefill row, so an over-subscribed budget still makes prompt progress
    instead of livelocking admission.

    Preemption (pool pressure): victim = max (class rank, admission
    ticket) — batch rows evict before standard before interactive, ties to
    the latest admission.  Aging protects QUEUE order only; a running
    interactive row never loses its pages to an aged batch row.
    """

    def __init__(self, budget: int, *, aging_steps: int = 64):
        if budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {budget}")
        self.budget = int(budget)
        self.aging_steps = max(1, int(aging_steps))

    def rank(self, req: Request) -> int:
        return SLO_CLASSES.get(req.slo_class, SLO_CLASSES["standard"])

    def queue_key(self, req: Request, now_step: int) -> tuple[int, int]:
        """Sort key for queued requests (lower = admitted first)."""
        enq = req.enqueued_step if req.enqueued_step is not None else now_step
        waited = max(0, now_step - enq)
        return (self.rank(req) - waited // self.aging_steps, enq)

    def victim_key(self, req: Request, ticket: int) -> tuple[int, int]:
        """Sort key for preemption victims (the MAX is evicted)."""
        return (self.rank(req), int(ticket))

    def split_chunks(
        self, decode_cost: int, remaining: dict[int, int], order: list[int],
    ) -> dict[int, int]:
        """Chunk sizes for this step's prefill rows.  `remaining[s]` prompt
        tokens are left on row s; `order` is priority order; decode rows
        (drafts included) already spent `decode_cost` of the budget.  Every
        row gets at least 1 token (forward progress), the leftover budget
        goes to the highest-priority rows first."""
        spare = max(self.budget - int(decode_cost), len(order))
        chunks = {s: 1 for s in order}
        spare -= len(order)
        for s in order:
            add = min(remaining[s] - 1, spare)
            if add > 0:
                chunks[s] += add
                spare -= add
        return chunks


class Engine:
    """Slot-based continuous batching on a fixed decode batch.

    decode_mode:
      "vectorized" (default) — one jitted decode per step for ALL active slots:
        per-slot `pos` vector through the model, donated cache buffers, caches
        replaced wholesale (inactive rows absorb masked-off writes that the
        next admission's prefill overwrites).
      "grouped" — the per-position-group dispatch loop with selective
        slot_merge; kept as the benchmark baseline.

    batch_prefill: admit every queued request that fits in one right-padded
    prefill call (attention-only, full-attention models; recurrent state and
    ring-buffer caches would absorb the pad garbage, so those families keep
    the exact per-slot prefill).  The paged path always batch-prefills — it
    prefills into a throwaway dense cache and scatters only real prompt
    blocks into the pool, so pad garbage never lands anywhere persistent and
    the flag has nothing to protect against.

    cache_mode:
      "paged" (default) — pool-of-pages KV with per-slot block tables,
        prefix reuse and preemption (module docstring).  Requires
        attention-only, no sliding window, vectorized decode; anything else
        auto-routes to dense.
      "dense" — the worst-case (slots, max_seq) reservation (parity baseline).

    sample: "greedy" (default) or "temperature" — per-slot temperature
    sampling (Request.temperature; <= 0 rows stay greedy) with a PRNG key
    folded per engine step from `seed`.  Note: paged preemption REPLAYS a
    request from scratch; greedy replay is deterministic, sampled replay
    draws fresh keys, so sampled engines under pool pressure are not
    replay-deterministic.

    spec_decode: speculative decode fast path.  Each step, a model-free
    prompt-lookup drafter (serving/spec.py, or the `drafter` override)
    proposes up to `draft_k` tokens per slot out of the slot's own token
    history; ONE batched verify dispatch (make_verify_step — decode-phase
    forward over the (B, L) draft window with per-row positions) scores
    them, and the engine commits the longest draft prefix that matches the
    model's own greedy argmax, plus the model's next token after it (1 to
    draft_k + 1 tokens per slot per dispatch).  Output is token-identical to
    plain greedy decode for ANY drafter; only throughput depends on draft
    quality.  Rejected draft positions need no dense-cache surgery (their
    K/V stays masked until overwritten) — but paged slots truncate back to
    the pages their committed length needs, returning draft-only pages to
    the pool (`audit()` stays exact).  Requires attention-only, no sliding
    window, vectorized decode, greedy sampling; anything else switches it
    off.

    token_budget: unified continuous batching (Sarathi-style).  Every step
    runs ONE mixed decode-phase dispatch whose (B, L) window packs decode
    rows (1 token each, or their spec-verify window) beside chunked-prefill
    rows (each spending a slice of the remaining budget on its prompt), so
    a long prompt admitted mid-decode streams into the cache WITHOUT ever
    pausing decode — zero decode-stall steps by construction, gated in
    benchmarks/check_regression.py.  Admission order, per-step budget
    split, and preemption ordering come from TokenBudgetScheduler
    (Request.slo_class + starvation-free aging).  A prefill row's final
    chunk yields its first generated token in the same dispatch, so output
    is token-identical to the phase-split engine.  Needs the spec-verify
    machinery (attention-only, no sliding window, vectorized decode,
    greedy); anything else turns it off and the phase-split path remains.

    stream_cb: optional callable (req, token) invoked synchronously as each
    token is committed — streaming output for servers (launch/serve.py).
    """

    def __init__(
        self,
        params,
        cfg,
        enc: EncodingConfig,
        config: EngineConfig | None = None,
        *,
        drafter: Callable | None = None,
        clock: Callable[[], float] | None = None,
        fault_hooks=None,
        stream_cb: Callable[[Request, int], None] | None = None,
        **kwargs,
    ):
        # ---- configuration (serving/config.py) -----------------------------
        # The engine's knobs live in one frozen, validated EngineConfig.
        # `Engine(params, cfg, enc, slots=8, ...)` remains supported as a
        # deprecation shim — the legacy kwargs are folded into
        # EngineConfig(**kwargs) — but config= is the first-class path.
        # Cross-field auto-downgrades (paged->dense, spec-off-under-sampling,
        # grouped decode for recurrent families) happen in config.resolve(),
        # not here; the applied rules are surfaced in stats["downgrades"].
        if config is None:
            config = EngineConfig(**kwargs)
        elif kwargs:
            raise TypeError(
                "pass either config=EngineConfig(...) or the legacy engine "
                f"kwargs, not both (got extra kwargs: {sorted(kwargs)})"
            )
        config = config.resolve(cfg)
        self.config = config
        self.cfg, self.enc = cfg, enc
        self.slots = config.slots
        self.max_seq = config.max_seq
        # ---- tensor parallelism (docs/PERF.md §Tensor-parallel capacity) ---
        # mesh_shape=(N,) with N > 1 shards the serving step across a device
        # mesh: weight streams column/row-parallel (parallel/sharding.py),
        # KV caches head-parallel (serving_cache_shardings), dispatch still
        # ONE jitted SPMD program per step — GSPMD inserts the single psum
        # per layer at the row-parallel wo/w_down matmuls.  Pallas custom
        # calls are not GSPMD-partitionable, so both op classes are routed
        # to the partitionable XLA paths under tp > 1 (recorded below).
        self.tp_shards = config.tp_shards
        self.mesh = None
        enc_downgrades: list[str] = []
        if config.mesh_devices > 1:
            self.mesh = mesh_lib.build_serving_mesh(
                config.mesh_shape, tp_axis=config.tp_axis
            )
        if self.tp_shards > 1:
            repl = {}
            if enc.backend not in ("xla", "reference"):
                repl["backend"] = "xla"
                enc_downgrades.append(f"backend:xla(tp,was={enc.backend})")
            if getattr(enc, "attn_backend", "xla") != "xla":
                repl["attn_backend"] = "xla"
                enc_downgrades.append(
                    f"attn_backend:xla(tp,was={enc.attn_backend})"
                )
            if repl:
                self.enc = enc = dataclasses.replace(enc, **repl)
        self.enc_downgrades = tuple(enc_downgrades)
        # kv4 packs two values per byte; only the pallas decode kernels
        # unpack nibbles tile-locally in VMEM.  Under an xla/reference
        # attention fallback (including the forced-xla tp path above) the
        # gather-and-dequant of packed nibbles is not worth the capacity win,
        # so kv4 rides the kv8 layout there — recorded like any other
        # resolve()-time downgrade.
        if (config.kv_quant == "kv4"
                and getattr(enc, "attn_backend", "xla")
                in ("xla", "reference")):
            config = dataclasses.replace(
                config, kv_quant="kv8",
                downgrades=config.downgrades + (
                    f"kv_quant:kv8(attn_backend="
                    f"{getattr(enc, 'attn_backend', 'xla')})",
                ),
            )
            self.config = config
        self.kv_quant = config.kv_quant
        self.params = params
        if self.mesh is not None:
            self.params = jax.device_put(
                params,
                sharding_lib.params_shardings(params, self.mesh, fsdp=False),
            )
        # ---- lifecycle / robustness (docs/ROBUSTNESS.md) -------------------
        # max_queue: admission-queue bound — submit() returns Rejected
        #   ("queue_full") past it instead of growing without bound.
        # clock: injectable monotonic clock (seconds) for deadlines and the
        #   step watchdog; the chaos layer passes FaultSchedule.clock so
        #   clock-skew faults are visible.
        # fault_hooks: object with on_step_begin / pre_dispatch /
        #   corrupt_slots / held_pages (serving/faults.FaultSchedule) —
        #   injection points, all no-ops when None.
        # logits_guard: non-finite check on committed logits; quarantines the
        #   offending slot only (measured overhead in docs/ROBUSTNESS.md).
        self.max_queue = config.max_queue
        self.clock = clock if clock is not None else time.monotonic
        self.hooks = fault_hooks
        self.logits_guard = bool(config.logits_guard)
        self.watchdog = watchdog_lib.DecodeStepWatchdog(clock=self.clock)
        self.rejected: list[Request] = []
        self.degraded: list[dict] = []
        self.lifecycle = {
            "rejected": 0, "cancelled": 0, "expired": 0,
            "kernel_faults": 0, "guard_trips": 0,
        }
        self.step_count = 0
        slots = config.slots
        max_seq = config.max_seq
        # Model-dependent mode downgrades (grouped decode for recurrent
        # families, paged->dense for sliding windows, spec/budget off where
        # the verify window cannot run) were applied by config.resolve().
        self.decode_mode = config.decode_mode
        self.cache_mode = config.cache_mode
        self.sample = config.sample
        self._base_key = jax.random.PRNGKey(config.seed)
        self._step_idx = 0
        self.draft_k = int(config.draft_k)
        self.spec_decode = bool(config.spec_decode)
        self.drafter = drafter if drafter is not None else spec_lib.propose
        self.token_budget = config.token_budget
        self.scheduler = (
            TokenBudgetScheduler(
                self.token_budget, aging_steps=config.slo_aging_steps
            )
            if self.token_budget is not None
            else None
        )
        self.stream_cb = stream_cb
        self._mixed_m = slots        # M of the imminent mixed dispatch
        self._window_blocks = 0      # table width the mixed window needs
        if self.scheduler is not None:
            self.continuous = {
                "token_budget": self.token_budget,
                "mixed_steps": 0,
                "decode_tokens": 0,        # decode-row window tokens dispatched
                "prefill_tokens": 0,       # prompt chunk tokens dispatched
                "decode_stall_steps": 0,   # steps where live decode rows emitted 0
                "chunked_admissions": 0,
                "completed_prefills": 0,
            }
        self._rebuild_dispatch_fns()
        if self.spec_decode:
            self.spec_stats = {
                "steps": 0,          # engine steps served by a verify dispatch
                "slot_steps": 0,     # per-slot verify participations
                "proposed": 0,       # draft tokens offered to verify
                "accepted": 0,       # draft tokens matching the greedy target
                "committed": 0,      # tokens emitted by spec steps (incl. bonus)
                "pool_deferred": 0,  # spec steps skipped: draft pages won't fit
            }
            self.slot_proposed = np.zeros(slots, np.int64)
            self.slot_accepted = np.zeros(slots, np.int64)
        if self.cache_mode == "paged":
            block_size = config.block_size
            pool_pages = config.pool_pages
            self.block_size = block_size
            self.num_blocks = -(-max_seq // block_size)
            if pool_pages is None:
                # Parity default: the pool covers the dense worst case, so
                # nothing preempts unless the caller shrinks it.
                pool_pages = 1 + slots * self.num_blocks
            # Tensor-parallel pools mirror one allocator per shard (page
            # identity must agree; COW/preemption/audit stay shard-local —
            # serving/paged.ShardedBlockAllocator).
            self.prefix_cache = bool(config.prefix_cache)
            self.tenant_quota = config.tenant_quota
            # Suffix-only prefill needs the bf16 prior-concat path: a kv8/kv4
            # gather would dequantize-requantize (bitwise drift vs cache
            # off), and sharded pools would gather per-shard head slices.
            # Those layouts still get the write-skip half of the cache win.
            self._suffix_ok = (
                self.kv_quant == "bf16" and self.tp_shards == 1
                and not cfg.sliding_window
            )
            self.alloc = (
                paged_lib.ShardedBlockAllocator(
                    pool_pages, block_size, shards=self.tp_shards,
                    kv_quant=self.kv_quant,
                    prefix_cache=self.prefix_cache,
                    tenant_quota=self.tenant_quota,
                )
                if self.tp_shards > 1
                else paged_lib.BlockAllocator(
                    pool_pages, block_size, self.kv_quant,
                    prefix_cache=self.prefix_cache,
                    tenant_quota=self.tenant_quota,
                )
            )
            self.caches = T.cache_init(
                cfg, slots, max_seq, cache_mode="paged",
                block_size=block_size, num_pages=pool_pages,
                kv_quant=self.kv_quant,
            )
            self.block_table = np.full(
                (slots, self.num_blocks), paged_lib.SCRATCH_PAGE, np.int32
            )
            self.slot_pages: list[list[int]] = [[] for _ in range(slots)]
            # Written-content tracking lives in the ALLOCATOR now
            # (BlockAllocator.written / mark_written): chunked prefill writes
            # lazily, but commit_prompt registers pages for prefix sharing
            # immediately — a later admission may only treat a shared page as
            # valid history once its owner's chunks have covered it (see
            # _admit_budget), and only written pages may be RETAINED in the
            # radix cache at refcount 0.  alloc() clears the marker on
            # recycle, so a re-allocated page can never carry a stale marker
            # into a future share.
            # Per-tenant worst-case page reservations for live admissions
            # (quota gate): tenant -> pages reserved by running requests.
            self._tenant_reserved: dict[str, int] = {}
            # Satellite-2 accounting: admissions that earlier DEFERRED on an
            # unwritten shared prefix and later re-planned into extra shared
            # blocks once the writer's chunks landed.
            self.deferred_hits = 0
            self.slot_ticket = np.zeros(slots, np.int64)
            self._ticket = 0
            self._tables_dirty = True
            self.preemptions = 0
            self.peak_active = 0
        else:
            self.caches = T.cache_init(cfg, slots, max_seq)
            # Prefix caching and page quotas are properties of the paged
            # pool; dense engines carry the neutral values so the shared
            # admission paths (e.g. _admit_budget's quota gate) stay
            # branch-free.
            self.prefix_cache = False
            self.tenant_quota = None
            self._tenant_reserved = {}
            self.deferred_hits = 0
        if self.mesh is not None:
            # Head-parallel KV: each shard holds its kv-head slice of every
            # cache page/row; block tables replicate (they mirror the host
            # table).  GSPMD propagates these shardings through the jitted
            # step, so attention runs collective-free until the per-layer
            # psum at the row-parallel output projection.
            self.caches = jax.device_put(
                self.caches,
                sharding_lib.serving_cache_shardings(self.caches, self.mesh),
            )
        self.slot_req: list[Request | None] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        # Prompt tokens already in the slot's cache — equals len(prompt) the
        # moment (batch) prefill runs; strictly less only mid-chunked-prefill
        # under the token-budget scheduler.
        self.slot_prefill_done = np.zeros(slots, np.int64)
        self.queue: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []
        self.batch_prefill = bool(config.batch_prefill)

    def _reject(self, req: Request, reason: str, detail: str) -> Rejected:
        req.status = "rejected"
        req.error = detail
        req.done = True
        self.rejected.append(req)
        self.lifecycle["rejected"] += 1
        return Rejected(req.uid, reason, detail)

    def submit(self, req: Request) -> Admitted | Rejected:
        """Admit `req` into the bounded queue, or refuse it with a structured
        reason — backpressure (queue_full) and up-front serviceability checks
        (a request that cannot ever fit the cache or the pool is rejected
        here, not admitted to preempt-thrash; the pool bound is the
        kv_capacity_requests math from core/encoding.py, applied to one
        request).  The result is truthy iff admitted."""
        req.submit_t = self.clock()
        req.enqueued_step = self.step_count
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            return self._reject(
                req, "queue_full",
                f"admission queue at max_queue={self.max_queue}; retry later",
            )
        if len(req.prompt) > self.max_seq:
            return self._reject(
                req, "unserviceable_seq",
                f"prompt of {len(req.prompt)} tokens exceeds max_seq "
                f"{self.max_seq}",
            )
        if self.cache_mode == "paged" and req.max_new_tokens > 0:
            # The most pages the request can ever hold (decode stops at
            # max_seq) must fit the pool, or admission could never run it —
            # this is blocks_per_request from encoding.kv_capacity_requests
            # evaluated at the request's own worst case.
            worst = self._worst_pages(req)
            if worst > self.alloc.capacity:
                return self._reject(
                    req, "unserviceable_pool",
                    f"request can need {worst} pages but the pool holds "
                    f"{self.alloc.capacity}; grow pool_pages or shrink the "
                    "request",
                )
            if self.tenant_quota is not None and worst > self.tenant_quota:
                # Same up-front serviceability logic as the pool bound: a
                # request whose worst case exceeds its tenant's whole quota
                # could never pass the admission gate — reject instead of
                # queuing it to starve.
                return self._reject(
                    req, "unserviceable_quota",
                    f"request can need {worst} pages but tenant "
                    f"{req.tenant!r} is capped at {self.tenant_quota}; raise "
                    "tenant_quota or shrink the request",
                )
        self.queue.append(req)
        return Admitted(req.uid)

    def _worst_pages(self, req: Request) -> int:
        """Worst-case page footprint of one request (decode stops at
        max_seq) — the quantity submit() checks against the pool and the
        quota gate reserves per tenant at admission."""
        worst_pos = min(len(req.prompt) + req.max_new_tokens, self.max_seq) - 1
        return worst_pos // self.block_size + 1

    def _quota_blocked(self, req: Request) -> bool:
        """Per-tenant admission gate: reserving this request's worst-case
        pages must keep its tenant within quota.  Reservations (not live
        usage) are the gated quantity so a tenant cannot over-admit on pages
        its running requests merely have not grown into yet."""
        if self.tenant_quota is None:
            return False
        reserved = self._tenant_reserved.get(req.tenant, 0)
        return reserved + self._worst_pages(req) > self.tenant_quota

    def _reserve_quota(self, req: Request) -> None:
        if self.tenant_quota is None:
            return
        pages = self._worst_pages(req)
        req._quota_pages = pages
        self._tenant_reserved[req.tenant] = (
            self._tenant_reserved.get(req.tenant, 0) + pages
        )

    def _release_quota(self, req: Request) -> None:
        pages = getattr(req, "_quota_pages", 0)
        if self.tenant_quota is None or not pages:
            return
        req._quota_pages = 0
        left = self._tenant_reserved.get(req.tenant, 0) - pages
        if left > 0:
            self._tenant_reserved[req.tenant] = left
        else:
            self._tenant_reserved.pop(req.tenant, None)

    # ---- guarded dispatch + kernel quarantine ------------------------------

    def _rebuild_dispatch_fns(self) -> None:
        """(Re)jit the serving dispatches.  Called at construction and after
        a kernel quarantine: a fresh jit object retraces on next call, so the
        model re-resolves its registry keys against the demoted ladder."""
        self.prefill_fn = jax.jit(make_prefill_step(self.cfg, self.enc))
        # Vectorized mode replaces the caches wholesale each step, so the old
        # buffers can be donated (in-place update on device, no copy).  The
        # grouped path re-reads self.caches after the call (merge) — no donate.
        donate = (1,) if self.decode_mode == "vectorized" else ()
        self.decode_fn = jax.jit(
            make_decode_step(self.cfg, self.enc, sample=self.sample),
            donate_argnums=donate,
        )
        if self.spec_decode:
            self.verify_fn = jax.jit(
                make_verify_step(self.cfg, self.enc), donate_argnums=(1,)
            )
        if getattr(self, "token_budget", None) is not None:
            self.mixed_fn = jax.jit(
                make_mixed_step(self.cfg, self.enc), donate_argnums=(1,)
            )
        if self.cache_mode == "paged":
            # Radix-cache suffix prefill: `pos` (tokens already served by
            # cached pages) is static — each distinct cached-prefix length
            # compiles once, like the chunked-prefill offsets.
            self.suffix_prefill_fn = jax.jit(
                make_suffix_prefill_step(self.cfg, self.enc),
                static_argnums=(3,),
            )

    def _attn_s(self, phase: Phase) -> int:
        """The logical KV length the next dispatch of `phase` attends — the
        S that keys its attention registry entry (mirrors stats)."""
        if phase is Phase.PREFILL:
            return self.max_seq
        if self.cache_mode == "paged":
            return self._live_table_width() * self.block_size
        if self.cfg.sliding_window:
            return min(self.max_seq, self.cfg.sliding_window)
        return self.max_seq

    def _dispatch_keys(self, kind: str) -> tuple[str, ...]:
        """Registry keys the imminent dispatch resolves through: its
        attention key plus its matmul key (quant mode x phase x M-bucket).
        These are what pre_dispatch faults match and what a quarantine
        demotes."""
        phase = Phase.PREFILL if kind == "prefill" else Phase.DECODE
        target_name = getattr(self.enc.target, "name", str(self.enc.target))
        quant = {"none": "none", "int8": "w8a8", "int4": "w4a8"}.get(
            getattr(self.enc, "weight_quant", "none"), "none"
        )
        m = {
            "prefill": self.slots * self.max_seq,
            "decode": self.slots,
            "verify": self.slots * (1 + self.draft_k),
            # The mixed window's M is slots x L, set per step — wide chunk
            # windows land in the "big" bucket, which routes to the packed
            # mmt4d GEMM (kernels/registry.py default policy).
            "mixed": self._mixed_m,
        }[kind]
        return (
            registry_lib.attn_dispatch_key(
                phase, self._attn_s(phase), target_name,
                kv=getattr(self, "kv_quant", "bf16"),
            ),
            registry_lib.dispatch_key(quant, phase, m, target_name),
        )

    def _requested_for(self, key: str) -> str | None:
        """The caller-pinned backend for a key's op class (the `requested`
        rung of its ladder) — attn_backend for attention keys, the encoding
        backend for matmul keys."""
        if key.startswith(registry_lib.ATTN_OP + "|"):
            return getattr(self.enc, "attn_backend", None)
        return getattr(self.enc, "backend", None)

    def _quarantine_kernel(
        self, key: str, reason: str, shard: int | None = None
    ) -> dict:
        """Demote `key` to the next rung of its dispatch ladder for the rest
        of the process (kernels/registry.demote), record it in
        stats["degraded"], and rebuild the jitted dispatches so the next
        trace resolves the demoted backend.  A shard-tagged fault demotes
        only that shard's ladder entry; the SPMD dispatch still honours it
        (select takes the max level over shards) but healthy shards keep
        their own observability rung."""
        requested = self._requested_for(key)
        before = registry_lib.resolve_key(key, requested=requested, shard=shard)
        record = registry_lib.demote(
            key, failing=before.backend, reason=reason,
            requested=requested, shard=shard,
        )
        entry = {"key": key, "step": self.step_count, **record}
        self.degraded.append(entry)
        self.lifecycle["kernel_faults"] += 1
        self._rebuild_dispatch_fns()
        if self.cache_mode == "paged":
            self._tables_dirty = True
        return entry

    def _dispatch(self, kind: str, fn_attr: str, *args):
        """Run one jitted dispatch through the fault/quarantine boundary:
        pre_dispatch hooks may raise a (simulated) KernelFaultError; a raise
        quarantines the named key and retries on the demoted rung.  Bounded
        by the ladder depth — a dispatch that still fails at the fallback
        rung propagates (there is nothing left to degrade to)."""
        for _attempt in range(4):
            keys = self._dispatch_keys(kind)
            try:
                if self.hooks is not None:
                    self.hooks.pre_dispatch(self, kind, keys)
                return getattr(self, fn_attr)(*args)
            except faults_lib.KernelFaultError as exc:
                self._quarantine_kernel(
                    exc.key, reason=str(exc),
                    shard=getattr(exc, "shard", None),
                )
                continue
        raise faults_lib.KernelFaultError(
            keys[0], "kernel dispatch still failing at the fallback rung"
        )

    # ---- lifecycle: deadlines, cancellation, the non-finite guard ----------

    def _past_deadline(self, req: Request) -> bool:
        return (
            req.deadline_ms is not None
            and req.submit_t is not None
            and (self.clock() - req.submit_t) * 1e3 > req.deadline_ms
        )

    def _finish_queued(self, req: Request, status: str, error: str | None) -> None:
        req.done = True
        req.status = status
        req.error = error
        self.finished.append(req)
        self.lifecycle[status] = self.lifecycle.get(status, 0) + 1

    def _admission_reap(self, req: Request) -> None:
        """Companion to the _reap_lifecycle sweep: the sweep reads the clock
        ONCE at the step boundary, but admission runs later in the same step
        (after prefill planning and page commits), so a deadline can lapse —
        or a cancel land — in between.  Without this re-check an
        already-dead request is admitted, prefilled, and only reaped a full
        step later: wasted dispatch work and, paged, pool pages committed to
        a corpse that can preempt a live request.  Caller has already popped
        `req` from the queue."""
        if req.cancel_requested:
            self._finish_queued(req, "cancelled", "cancelled while queued")
        else:
            self._finish_queued(
                req, "expired",
                f"deadline_ms={req.deadline_ms} exceeded at admission",
            )

    def _reap_lifecycle(self) -> None:
        """Step-boundary lifecycle sweep: cancelled and deadline-expired
        requests finish NOW, queued or running — running slots free their
        pages through the same _finish_slot path as normal completion, so
        the allocator audit stays exact."""
        if self.queue and any(
            r.cancel_requested or self._past_deadline(r) for r in self.queue
        ):
            kept: collections.deque[Request] = collections.deque()
            for req in self.queue:
                if req.cancel_requested:
                    self._finish_queued(req, "cancelled", "cancelled while queued")
                elif self._past_deadline(req):
                    self._finish_queued(
                        req, "expired",
                        f"deadline_ms={req.deadline_ms} exceeded while queued",
                    )
                else:
                    kept.append(req)
            self.queue = kept
        for s in range(self.slots):
            req = self.slot_req[s]
            if req is None:
                continue
            if req.cancel_requested:
                self._finish_slot(s, status="cancelled", error="cancelled mid-flight")
            elif self._past_deadline(req):
                self._finish_slot(
                    s, status="expired",
                    error=f"deadline_ms={req.deadline_ms} exceeded mid-flight",
                )

    def _guard_slots(self, logits, active: list[int]) -> frozenset[int]:
        """The non-finite guard: slots whose logit rows this step are not
        finite (hook-injected corruption included — the chaos layer NaNs
        rows here so the REAL guard sees real non-finite data).  One (B,)
        device reduction + host transfer per step; overhead measured in
        benchmarks (docs/ROBUSTNESS.md)."""
        if self.hooks is not None:
            forced = self.hooks.corrupt_slots(self, active)
            if forced:
                logits = logits.at[jnp.asarray(forced, jnp.int32)].set(jnp.nan)
        if not self.logits_guard:
            return frozenset()
        ok = np.asarray(
            jnp.all(jnp.isfinite(logits), axis=tuple(range(1, logits.ndim)))
        )
        bad = frozenset(s for s in active if not ok[s])
        if bad:
            self.lifecycle["guard_trips"] += len(bad)
        return bad

    def poison_slot_kv(self, s: int) -> None:
        """Overwrite slot `s`'s most recent KV storage with NaN — the chaos
        layer's cache-poisoning injection (a kernel writing garbage K/V).
        The slot's next logits go non-finite and the guard quarantines it;
        pages are slot-private unless prefix-shared, so co-batched slots
        only see the poison when they genuinely share the page.

        Quantized layouts (kv8/kv4) store integer page data, which cannot
        hold a NaN — the data pages get a saturating garbage sentinel and
        the float32 scale pages get the NaN, so dequantize (int * NaN
        scale) still produces the non-finite logits the guard trips on."""
        nan = jnp.nan
        if self.cache_mode == "paged":
            if not self.slot_pages[s]:
                return
            page = self.slot_pages[s][-1]

            def one(path, leaf):
                if str(getattr(path[-1], "key", "")) == "table":
                    return leaf
                poison = (
                    jnp.iinfo(leaf.dtype).max
                    if jnp.issubdtype(leaf.dtype, jnp.integer) else nan
                )
                if _batch_axis(path) == 1:
                    return leaf.at[:, page].set(poison)
                return leaf.at[page].set(poison)

        else:
            pos = max(int(self.slot_pos[s]) - 1, 0)

            def one(path, leaf):
                if str(getattr(path[-1], "key", "")) == "table":
                    return leaf
                if leaf.ndim < 2:
                    return leaf
                if _batch_axis(path) == 1:
                    return leaf.at[:, s, pos % leaf.shape[2]].set(nan)
                return leaf.at[s, pos % leaf.shape[1]].set(nan)

        self.caches = jax.tree_util.tree_map_with_path(one, self.caches)

    # ---- paged admission / page management ---------------------------------

    def _finish_degenerate(self, req: Request) -> None:
        req.done = True
        req.status = "ok"
        self.finished.append(req)

    def _admit_paged(self):
        free = [s for s in range(self.slots) if self.slot_req[s] is None]
        batch: list[tuple[int, Request, paged_lib.PagePlan]] = []
        # Radix-cache admissions whose whole shared run is already WRITTEN:
        # prefill computes only the un-cached suffix ((slot, req, plan, lead)).
        suffix: list[tuple[int, Request, paged_lib.PagePlan, int]] = []
        for req in list(self.queue):
            if not free:
                break
            if req.max_new_tokens <= 0:
                self.queue.remove(req)
                self._finish_degenerate(req)
                continue
            if req.cancel_requested or self._past_deadline(req):
                # Deadline/cancel re-check at admission time (the _reap
                # sweep's snapshot can lapse within the same step).
                self.queue.remove(req)
                self._admission_reap(req)
                continue
            if self._quota_blocked(req):
                # Tenant over quota: skip THIS request but keep scanning —
                # one tenant's quota pressure must not become head-of-line
                # blocking for every other tenant's queued work.
                continue
            nblocks, shared = self.alloc.plan_prompt(req.prompt)
            if not self.alloc.plan_fits(nblocks, shared):
                break  # pool pressure: stop admitting (FIFO order preserved)
            # Leading run of shared blocks whose K/V has LANDED in the pool:
            # those token ranges can skip prefill compute entirely.  Shares
            # of unwritten pages (an admission earlier in this same batch)
            # still reuse the pages — the batched prefill below writes them
            # this very step — but force the full-prefill path.
            lead = 0
            while lead in shared and self.alloc.is_written(shared[lead]):
                lead += 1
            plan = self.alloc.commit_prompt(
                req.prompt, nblocks, shared, tenant=req.tenant
            )
            assert plan is not None
            self.queue.remove(req)
            self._reserve_quota(req)
            s = free.pop(0)
            if lead == len(shared) and lead > 0 and self._suffix_ok:
                suffix.append((s, req, plan, lead))
            else:
                batch.append((s, req, plan))
        if not batch and not suffix:
            return
        if batch:
            # ONE right-padded batched prefill into a TEMPORARY dense cache
            # (pad rounds to a power of two >= block_size, so padded lengths
            # are block-aligned and compiled shapes stay
            # O(slots * log(max_seq))), then scatter the computed K/V blocks
            # into their pool pages.  Shared prefix pages are NOT rewritten:
            # suffix zero-padding is exact in the chunked attention, so the
            # original owner's prefill already wrote bitwise-identical
            # content (the conformance tests pin this).
            maxlen = max(len(r.prompt) for _, r, _ in batch)
            lp = max(
                self.block_size,
                min(1 << (maxlen - 1).bit_length(),
                    self.num_blocks * self.block_size),
            )
            toks = np.zeros((len(batch), lp), np.int32)
            for i, (_, r, _) in enumerate(batch):
                toks[i, : len(r.prompt)] = r.prompt
            tmp = T.cache_init(self.cfg, len(batch), lp)
            _, tmp = self._dispatch(
                "prefill", "prefill_fn", self.params, jnp.asarray(toks), tmp
            )
            self._scatter_prefill(tmp, batch)
        for s, r, plan, lead in suffix:
            self._prefill_suffix(r, plan, lead)
        for s, r, plan in batch + [(s, r, p) for s, r, p, _ in suffix]:
            self.slot_req[s] = r
            r.status = "running"
            self.slot_pos[s] = len(r.prompt)
            self.slot_prefill_done[s] = len(r.prompt)
            self.slot_pages[s] = list(plan.pages)
            self.alloc.claim_owner(plan.pages, s)
            self.alloc.mark_written(plan.pages)
            self.block_table[s, :] = paged_lib.SCRATCH_PAGE
            self.block_table[s, : len(plan.pages)] = plan.pages
            self.slot_ticket[s] = self._ticket
            self._ticket += 1
        self._tables_dirty = True

    def _scatter_prefill(self, tmp, batch) -> None:
        """Write each admitted request's non-shared prompt blocks from the
        temporary dense prefill cache into their pool pages — one gather +
        one scatter per cache leaf.

        The temp prefill cache is always raw bf16 (flash prefill computes
        full-precision K/V); under a quantized layout the block gather is
        quantized HERE, page-granular, and the per-page scales land in the
        sibling `k_scale`/`v_scale` leaves at the same page ids.  jax sorts
        dict keys, so within a layer the `k`/`v` data leaf is always
        visited before its `{k,v}_scale` leaf — the data visit stashes the
        computed scales keyed by the scale leaf's path."""
        bs = self.block_size
        ri: list[int] = []
        bi: list[int] = []
        pgs: list[int] = []
        for i, (_, _r, plan) in enumerate(batch):
            for j, (pg, sh) in enumerate(zip(plan.pages, plan.shared)):
                if not sh:
                    ri.append(i)
                    bi.append(j)
                    pgs.append(pg)
        if not pgs:
            return
        ria = jnp.asarray(ri, jnp.int32)
        bia = jnp.asarray(bi, jnp.int32)
        pga = jnp.asarray(pgs, jnp.int32)
        flat, _ = jax.tree_util.tree_flatten_with_path(tmp)
        tmp_by_path = {jax.tree_util.keystr(p): v for p, v in flat}
        layout = encoding_lib.kv_layout(getattr(self, "kv_quant", "bf16"))
        pending_scales: dict[str, jax.Array] = {}

        def one(path, leaf):
            name = str(getattr(path[-1], "key", ""))
            if name == "table":
                return leaf
            key = jax.tree_util.keystr(path)
            if name in ("k_scale", "v_scale"):
                sc = pending_scales.pop(key)
                if _batch_axis(path) == 1:
                    return leaf.at[:, pga].set(sc)
                return leaf.at[pga].set(sc)
            part = tmp_by_path[key]
            if _batch_axis(path) == 1:  # stacked groups: (G, B, Lp, KV, HD)
                g, nb, lpad, kvh, hd = part.shape
                pr = part.reshape(g, nb, lpad // bs, bs, kvh, hd)
                blocks = pr[:, ria, bia]
                if layout.quantized:
                    blocks, sc = layout.quantize(blocks)
                    pending_scales[
                        key.replace(f"['{name}']", f"['{name}_scale']")
                    ] = sc
                return leaf.at[:, pga].set(blocks)
            nb, lpad, kvh, hd = part.shape
            pr = part.reshape(nb, lpad // bs, bs, kvh, hd)
            blocks = pr[ria, bia]
            if layout.quantized:
                blocks, sc = layout.quantize(blocks)
                pending_scales[
                    key.replace(f"['{name}']", f"['{name}_scale']")
                ] = sc
            return leaf.at[pga].set(blocks)

        self.caches = jax.tree_util.tree_map_with_path(one, self.caches)
        assert not pending_scales, (
            f"scale pages never scattered: {sorted(pending_scales)}"
        )

    def _gather_prefix(self, tmp, pages: list[int]):
        """Copy written pool pages into the leading rows of a temp dense
        prefill cache — the cached-prefix K/V a suffix prefill attends
        through the prior-concat path.  bf16 layout only (the suffix path is
        gated off under kv8/kv4: a dequantize-requantize round trip would
        break bitwise identity with the cache-off run)."""
        if not pages:
            return tmp
        bs = self.block_size
        n = len(pages)
        pga = jnp.asarray(pages, jnp.int32)
        flat, _ = jax.tree_util.tree_flatten_with_path(self.caches)
        pool_by_path = {jax.tree_util.keystr(p): v for p, v in flat}

        def one(path, leaf):
            name = str(getattr(path[-1], "key", ""))
            if name not in ("k", "v"):
                return leaf
            pool = pool_by_path[jax.tree_util.keystr(path)]
            if _batch_axis(path) == 1:  # stacked groups (G, P, bs, KV, HD)
                blocks = pool[:, pga]   # (G, n, bs, KV, HD)
                seq = blocks.reshape(
                    blocks.shape[0], 1, n * bs, *blocks.shape[3:]
                )
                return leaf.at[:, :, : n * bs].set(seq.astype(leaf.dtype))
            blocks = pool[pga]          # (n, bs, KV, HD)
            seq = blocks.reshape(1, n * bs, *blocks.shape[2:])
            return leaf.at[:, : n * bs].set(seq.astype(leaf.dtype))

        return jax.tree_util.tree_map_with_path(one, tmp)

    def _prefill_suffix(self, req: Request, plan: paged_lib.PagePlan,
                        lead: int) -> None:
        """Radix-cache admission with the first `lead` blocks' K/V already
        in the pool: prefill computes ONLY the un-cached suffix.  The cached
        prefix is gathered into a temp dense cache, the suffix runs as a
        PREFILL at static offset lead*block_size (the prior-concat resume
        path chunked prefill uses), and the computed suffix blocks scatter
        into the plan's private pages — the prefill-FLOPs-saved half of the
        cache win (the write-skip half applies on every layout; see
        docs/PERF.md §Prefix caching)."""
        bs = self.block_size
        skip = lead * bs
        plen = len(req.prompt)
        lp = max(bs, min(1 << (plen - 1).bit_length(), self.num_blocks * bs))
        tmp = T.cache_init(self.cfg, 1, lp)
        tmp = self._gather_prefix(tmp, plan.pages[:lead])
        toks = np.zeros((1, lp - skip), np.int32)
        toks[0, : plen - skip] = np.asarray(req.prompt[skip:], np.int32)
        _, tmp = self._dispatch(
            "prefill", "suffix_prefill_fn", self.params, jnp.asarray(toks),
            tmp, skip,
        )
        self._scatter_prefill(tmp, [(None, req, plan)])

    def _live_table_width(self) -> int:
        """Logical block-table width the NEXT decode dispatch needs: the max
        allocated page count over active slots, bucketed to a power of two
        (compiled decode shapes stay O(log num_blocks)).  Short sequences
        then stop paying for empty trailing table entries — the paged
        attention kernel's grid and the fallback `paged_gather` both scale
        with the table width they are handed.  Tiny tables skip the
        narrowing entirely: each width bucket is a fresh decode compile,
        and below ~8 blocks the recompiles cost more than the few spare
        block reads they save."""
        if self.num_blocks <= 8:
            return self.num_blocks
        # The mixed step widens the table to cover its whole window, pads
        # included: a pad past the table width would clamp onto the row's
        # LAST REAL page (models/layers.py) and corrupt committed history,
        # while inside the width it lands on scratch or a masked future
        # offset of a private page.  _window_blocks is 0 outside mixed steps.
        live = max(1, self._window_blocks)
        for s in range(self.slots):
            if self.slot_req[s] is not None:
                live = max(live, len(self.slot_pages[s]))
        return min(self.num_blocks, 1 << (live - 1).bit_length())

    def _with_tables(self, caches):
        """Refresh every `table` cache leaf from the host block table,
        narrowed to the live-width bucket (_live_table_width)."""
        tbl = self.block_table[:, : self._live_table_width()]

        def one(path, leaf):
            if str(getattr(path[-1], "key", "")) == "table":
                shape = leaf.shape[:-1] + (tbl.shape[-1],)
                return jnp.asarray(np.broadcast_to(tbl, shape))
            return leaf

        return jax.tree_util.tree_map_with_path(one, caches)

    def _preempt(self, s: int) -> None:
        """Evict slot `s`: free its pages and requeue its request at the
        queue front.  Greedy decode is deterministic, so the replay emits
        the same tokens the uninterrupted run would have."""
        req = self.slot_req[s]
        req.generated.clear()
        req.draft_proposed = req.draft_accepted = 0  # replay re-accounts
        req.status = "queued"
        self._release_quota(req)
        self.alloc.free_pages(self.slot_pages[s], owner=s, tenant=req.tenant)
        self.slot_pages[s] = []
        self.block_table[s, :] = paged_lib.SCRATCH_PAGE
        self.slot_req[s] = None
        self.slot_pos[s] = 0
        self.slot_prefill_done[s] = 0  # replay re-runs (chunked) prefill
        self.queue.appendleft(req)
        self._tables_dirty = True
        self.preemptions += 1

    def _victim_key(self, v: int):
        """Preemption priority — the MAX of this key over live slots is
        evicted.  Phase-split engines keep the original rule (latest
        admission ticket).  Under the token-budget scheduler, SLO class
        outranks ticket: batch rows evict before standard before
        interactive, ties to the latest admission (aging protects queue
        order only; a running interactive row never loses its pages to an
        aged batch row — docs/ROBUSTNESS.md)."""
        if self.scheduler is not None:
            return self.scheduler.victim_key(self.slot_req[v], self.slot_ticket[v])
        return self.slot_ticket[v]

    def _ensure_decode_pages(self, extra: int = 0) -> None:
        """Decode growth: each active slot must own the page its next token
        writes into — and, with `extra` > 0 (the speculative-decode verify
        window), the pages of the `extra` draft positions after it too."""
        self._ensure_pages({
            s: max(int(self.slot_pos[s]) - 1, 0) + extra
            for s in range(self.slots)
            if self.slot_req[s] is not None
        })

    def _ensure_pages(self, ends: dict[int, int]) -> None:
        """Grow each slot's pages to cover its last write position
        (`ends[s]`, absolute — the mixed step passes per-row window ends).
        Allocate at block boundaries in admission order; when the pool is
        dry, preempt the lowest-priority slot (_victim_key) until a page
        frees — possibly the requesting slot itself."""
        order = sorted(ends, key=lambda s: self.slot_ticket[s])
        for s in order:
            if self.slot_req[s] is None:
                continue  # preempted while serving an earlier slot
            need = ends[s] // self.block_size + 1
            while self.slot_req[s] is not None and len(self.slot_pages[s]) < need:
                page = self.alloc.alloc(
                    owner=s, tenant=self.slot_req[s].tenant
                )
                if page is None:
                    victims = [
                        v for v in range(self.slots) if self.slot_req[v] is not None
                    ]
                    victim = max(victims, key=self._victim_key)
                    self._preempt(victim)
                    continue
                self.slot_pages[s].append(page)
                self.block_table[s, len(self.slot_pages[s]) - 1] = page
                self._tables_dirty = True

    @property
    def stats(self) -> dict:
        out = {
            "cache_mode": self.cache_mode,
            "decode_mode": self.decode_mode,
            "sample": self.sample,
            # KV-cache storage layout (core/encoding.kv_layout): bf16, or a
            # quantized paged layout (kv8/kv4) with per-page scales.
            "kv_quant": getattr(self, "kv_quant", "bf16"),
            # Serving weight format (drives the decode weight-stream roofline;
            # see encoding.quant_weight_stream_bytes and docs/PERF.md).
            "weight_quant": self.enc.weight_quant,
            # Resolved attention op-class backend for this engine's CURRENT
            # decode regime (kernels/registry.py select_attn; "pallas" = the
            # kernels/attn.py microkernels, "xla" = the jnp references).
            # The S the dispatches actually see: the live-narrowed table
            # width for paged caches, the ring width for sliding windows.
            "attn_backend": registry_lib.select_attn(
                phase=Phase.DECODE,
                s=(
                    self._live_table_width() * self.block_size
                    if self.cache_mode == "paged"
                    else min(self.max_seq, self.cfg.sliding_window)
                    if self.cfg.sliding_window
                    else self.max_seq
                ),
                target=self.enc.target,
                requested=getattr(self.enc, "attn_backend", "xla"),
                kv=getattr(self, "kv_quant", "bf16"),
            ).backend,
            # ---- robustness observables (docs/ROBUSTNESS.md) ---------------
            "steps": self.step_count,
            "watchdog": self.watchdog.summary(),
            "lifecycle": dict(self.lifecycle),
            # Kernel-quarantine events this process: [{key, step, level,
            # from, to, reason}] — the degradation ladder's audit trail.
            "degraded": [dict(d) for d in self.degraded],
        }
        if self.tp_shards > 1:
            # Per-shard observability under tensor parallelism: the resolved
            # attention backend each shard's ladder would pick (the SPMD
            # dispatch itself runs the max-quarantined rung over shards), and
            # each shard's slice of the degradation trail (global events
            # appear in every shard's list).  Legacy string/list forms are
            # preserved at tp==1 so single-device callers are untouched.
            attn_s = (
                self._live_table_width() * self.block_size
                if self.cache_mode == "paged"
                else min(self.max_seq, self.cfg.sliding_window)
                if self.cfg.sliding_window
                else self.max_seq
            )
            out["attn_backend"] = {
                k: registry_lib.select_attn(
                    phase=Phase.DECODE,
                    s=attn_s,
                    target=self.enc.target,
                    requested=getattr(self.enc, "attn_backend", "xla"),
                    kv=getattr(self, "kv_quant", "bf16"),
                    shard=k,
                ).backend
                for k in range(self.tp_shards)
            }
            out["degraded"] = {
                k: [
                    dict(d) for d in self.degraded
                    if d.get("shard") in (None, k)
                ]
                for k in range(self.tp_shards)
            }
            out["tp"] = {
                "shards": self.tp_shards,
                "mesh_shape": list(self.config.mesh_shape),
                "tp_axis": self.config.tp_axis,
                "enc_downgrades": list(self.enc_downgrades),
            }
        if self.config.downgrades:
            out["config_downgrades"] = list(self.config.downgrades)
        if self.spec_decode:
            st = dict(self.spec_stats)
            # Amortization terms (docs/PERF.md §Speculative decode): a slot's
            # verify commits mean_accepted_len tokens per dispatch, so decode
            # dispatches per token is its reciprocal.
            st["acceptance_rate"] = st["accepted"] / max(st["proposed"], 1)
            st["mean_accepted_len"] = st["committed"] / max(st["slot_steps"], 1)
            st["per_slot_proposed"] = self.slot_proposed.tolist()
            st["per_slot_accepted"] = self.slot_accepted.tolist()
            out["spec"] = st
            out["draft_k"] = self.draft_k
        if self.scheduler is not None:
            out["continuous"] = dict(self.continuous)
        if self.cache_mode == "paged":
            astats = self.alloc.stats
            out.update(astats)
            out.update(
                pages_total=self.alloc.capacity,
                pages_in_use=self.alloc.in_use(),
                pages_free=self.alloc.available(),
                preemptions=self.preemptions,
                peak_active=self.peak_active,
                block_size=self.block_size,
            )
            # Radix prefix-cache observability, one shape-stable dict at
            # every tp degree (the PR-9 normalization rule: reporting code
            # must not care about the mesh — per-shard copies of these
            # counters are asserted identical by ShardedBlockAllocator and
            # also appear under tp.per_shard_pages).
            out["prefix_cache"] = {
                "enabled": self.prefix_cache,
                "hit_blocks": astats["hit_blocks"],
                "hit_tokens": astats["hit_tokens"],
                "lookup_blocks": astats["lookup_blocks"],
                "hit_rate": (
                    astats["hit_blocks"] / astats["lookup_blocks"]
                    if astats["lookup_blocks"] else 0.0
                ),
                "evictions": astats["evictions"],
                "cached_pages": astats["cached_pages"],
                "deferred_hits": self.deferred_hits,
            }
            if self.tenant_quota is not None:
                out["prefix_cache"]["tenant_quota"] = self.tenant_quota
                out["prefix_cache"]["tenant_usage"] = self.alloc.tenant_usage()
            if self.tp_shards > 1:
                out["tp"]["per_shard_pages"] = self.alloc.per_shard_stats()
        return out

    def stats_view(self) -> dict:
        """`stats` with a SHAPE-STABLE schema across tp degrees.

        The raw `stats` property keeps its legacy forms — a scalar
        `attn_backend` string and a flat `degraded` list at tp==1, per-shard
        dicts at tp>1 — because both shapes are pinned by existing callers
        and tests.  Reporting code that must not care about the mesh (e.g.
        launch/serve.py) uses this accessor instead: `attn_backend` and
        `degraded` are ALWAYS {shard -> value} dicts, with the single-device
        engine presented as shard 0."""
        out = self.stats
        if self.tp_shards == 1:
            out["attn_backend"] = {0: out["attn_backend"]}
            out["degraded"] = {0: out["degraded"]}
        return out

    def audit(self) -> None:
        """Assert allocator/table consistency (tests call this every step).
        Pages seized by an active fault schedule (pool_spike holds) are
        legitimate references, not leaks — fold them in as one extra table
        so the exact-partition check keeps holding under chaos."""
        if self.cache_mode != "paged":
            return
        tables = [
            self.slot_pages[s] for s in range(self.slots)
            if self.slot_req[s] is not None
        ]
        held = (
            list(self.hooks.held_pages())
            if self.hooks is not None and hasattr(self.hooks, "held_pages")
            else []
        )
        if held:
            tables = tables + [held]
        self.alloc.audit(tables)

    # ---- dense admission ---------------------------------------------------

    def _admit(self):
        if self.scheduler is not None:
            return self._admit_budget()
        if self.cache_mode == "paged":
            return self._admit_paged()
        free = [s for s in range(self.slots) if self.slot_req[s] is None]
        batch: list[tuple[int, Request]] = []
        while free and self.queue:
            req = self.queue.popleft()
            if req.max_new_tokens <= 0:
                # Degenerate request: nothing to decode — never occupies a slot.
                self._finish_degenerate(req)
                continue
            if req.cancel_requested or self._past_deadline(req):
                # Deadline/cancel re-check at admission time (the _reap
                # sweep's snapshot can lapse within the same step).
                self._admission_reap(req)
                continue
            batch.append((free.pop(0), req))
        if not batch:
            return
        if self.batch_prefill and len(batch) > 1:
            # One right-padded prefill for every admitted request.  Pad tokens
            # only write cache positions the decode mask (slot <= pos) never
            # reads before a real token overwrites them.  The pad length
            # rounds up to a power of two so the jitted prefill compiles for
            # O(slots * log(max_seq)) shapes, not one per distinct maxlen.
            slots_sel = [s for s, _ in batch]
            maxlen = max(len(r.prompt) for _, r in batch)
            maxlen = min(1 << (maxlen - 1).bit_length(), self.max_seq)
            toks = np.zeros((len(batch), maxlen), np.int32)
            for i, (_, r) in enumerate(batch):
                toks[i, : len(r.prompt)] = r.prompt
            part = slot_gather(self.caches, slots_sel)
            _, part = self._dispatch(
                "prefill", "prefill_fn", self.params, jnp.asarray(toks), part
            )
            self.caches = slot_merge(
                self.caches, part, slots_sel, list(range(len(batch)))
            )
        else:
            for s, r in batch:
                # Per-slot prefill: batch of 1 through a slot-sliced cache view.
                toks = jnp.asarray(r.prompt, jnp.int32)[None]
                slot_cache = slot_slice(self.caches, s)
                _, slot_cache = self._dispatch(
                    "prefill", "prefill_fn", self.params, toks, slot_cache
                )
                self.caches = slot_merge(self.caches, slot_cache, [s], [0])
        for s, r in batch:
            self.slot_req[s] = r
            r.status = "running"
            self.slot_pos[s] = len(r.prompt)
            self.slot_prefill_done[s] = len(r.prompt)

    # ---- token-budget admission (no prefill dispatch) ----------------------

    def _admit_budget(self) -> None:
        """Admission under the token-budget scheduler: NO prefill dispatch
        here — an admitted request's prompt streams into the cache through
        the mixed step's chunk rows (slot_prefill_done tracks progress), so
        admitting a 4k-token prompt costs this step nothing.  Candidates
        are taken in SLO priority order (TokenBudgetScheduler.queue_key)
        instead of FIFO; pool pressure stops admission at the first
        candidate that does not fit, so a smaller request never jumps a
        starved larger one.  Paged prompts commit their whole page plan up
        front; leading prefix-shared pages are reused VERBATIM —
        slot_prefill_done starts past them, so a chunk row never rewrites a
        shared page and the COW boundary stays exact even when the shared
        prefix is not chunk- or block-aligned (the partial boundary block
        was already COW-split by plan_prompt/commit_prompt)."""
        free = [s for s in range(self.slots) if self.slot_req[s] is None]
        if not free or not self.queue:
            return
        candidates = sorted(
            self.queue,
            key=lambda r: self.scheduler.queue_key(r, self.step_count),
        )
        for req in candidates:
            if not free:
                break
            if req.max_new_tokens <= 0:
                self.queue.remove(req)
                self._finish_degenerate(req)
                continue
            if req.cancel_requested or self._past_deadline(req):
                # Deadline/cancel re-check at admission time (the _reap
                # sweep's snapshot can lapse within the same step).
                self.queue.remove(req)
                self._admission_reap(req)
                continue
            if self._quota_blocked(req):
                # Tenant over quota: skip (never `break` — other tenants'
                # queued work must keep flowing past a capped tenant).
                continue
            done = 0
            if self.cache_mode == "paged":
                nblocks, shared = self.alloc.plan_prompt(req.prompt)
                # Share only pages whose content has actually LANDED:
                # commit_prompt registers pages before any chunk writes
                # them (chunked prefill is lazy), and a row prefilling
                # from INSIDE a shared block sprays its window-pad writes
                # (positions past its chunk, garbage K/V) across the
                # owner's history.  Truncating the plan at the first
                # unwritten page keeps this row's entire write range —
                # real chunks AND pads — inside private pages: written
                # shared pages are skipped outright (slot_prefill_done
                # starts past them), unwritten ones are never shared.
                lead = 0
                while (lead in shared
                       and self.alloc.is_written(shared[lead])):
                    lead += 1
                if lead < len(shared) and self._defer_for_writer(req, lead):
                    # Declined unwritten shares need not be FORFEITED: the
                    # writer's chunks are still landing, so re-check the
                    # tree at this request's next admission opportunity
                    # instead of committing a recomputed private copy now.
                    # Bounded (_DEFER_CAP) so a stalled writer cannot
                    # park a candidate forever.
                    continue
                if getattr(req, "_defer_lead", None) is not None:
                    # Admitted after deferring: every block the wait turned
                    # from an unwritten decline into a real share is a hit
                    # the old code silently forfeited.
                    self.deferred_hits += max(0, lead - req._defer_lead)
                    req._defer_lead = None
                shared = {j: p for j, p in shared.items() if j < lead}
                if not self.alloc.plan_fits(nblocks, shared):
                    break  # pool pressure: the head candidate waits
                plan = self.alloc.commit_prompt(
                    req.prompt, nblocks, shared, tenant=req.tenant
                )
                assert plan is not None
                s = free.pop(0)
                self.slot_pages[s] = list(plan.pages)
                self.alloc.claim_owner(plan.pages, s)
                self.block_table[s, :] = paged_lib.SCRATCH_PAGE
                self.block_table[s, : len(plan.pages)] = plan.pages
                self.slot_ticket[s] = self._ticket
                self._ticket += 1
                self._tables_dirty = True
                done = lead * self.block_size
                self._reserve_quota(req)
            else:
                s = free.pop(0)
            self.queue.remove(req)
            self.slot_req[s] = req
            req.status = "running"
            self.slot_prefill_done[s] = done
            self.slot_pos[s] = done
            self.continuous["chunked_admissions"] += 1

    # A candidate declining unwritten prefix shares re-checks the tree for at
    # most this many admission opportunities before giving up and recomputing
    # the prefix privately (satellite: deferred_hits).
    _DEFER_CAP = 4

    def _defer_for_writer(self, req: Request, lead: int) -> bool:
        """Whether to hold `req` out of this admission round because part of
        its tree-matched prefix is still unwritten (the writer's chunks are
        in flight).  Records the written lead at defer time so the eventual
        admission can count the blocks the wait recovered."""
        count = getattr(req, "_defer_count", 0)
        if count >= self._DEFER_CAP:
            return False
        req._defer_count = count + 1
        req._defer_lead = lead
        return True

    def _finish_slot(self, s: int, *, status: str = "ok",
                     error: str | None = None) -> None:
        """Retire slot `s` with a terminal status.  EVERY slot exit — normal
        completion, cancel, deadline expiry, guard trip — funnels through
        here, so page release and table reset are a single code path the
        allocator audit can hold exactly."""
        req = self.slot_req[s]
        req.done = True
        req.status = status
        req.error = error
        self.finished.append(req)
        if status != "ok":
            self.lifecycle[status] = self.lifecycle.get(status, 0) + 1
        self.slot_req[s] = None
        self.slot_pos[s] = 0  # freed rows decode (discarded) at pos 0
        self.slot_prefill_done[s] = 0
        if self.cache_mode == "paged":
            # Released-on-finish: every page's refcount drops; table row back
            # to scratch.  With the prefix cache on, registered+written
            # blocks whose refcount hits 0 are PARKED in the radix tree
            # (state "cached") instead of freed — this release IS the
            # insert-on-finish the radix cache lives on.  Everything else
            # (trailing decode pages, partial blocks) frees as before.
            self._release_quota(req)
            self.alloc.free_pages(self.slot_pages[s], owner=s,
                                  tenant=req.tenant)
            self.slot_pages[s] = []
            self.block_table[s, :] = paged_lib.SCRATCH_PAGE
            self._tables_dirty = True

    def _commit_tokens(self, s: int, toks: list[int]) -> int:
        """Append `toks` to slot s in order, honouring EOS / max_new_tokens /
        max_seq mid-list (spec decode commits several tokens per dispatch; a
        finish condition truncates the rest — post-EOS tokens are never
        emitted).  Returns how many tokens were emitted."""
        req = self.slot_req[s]
        emitted = 0
        for t in toks:
            req.generated.append(t)
            self.slot_pos[s] += 1
            emitted += 1
            if self.stream_cb is not None:
                self.stream_cb(req, int(t))
            if (
                (req.eos_id is not None and t == req.eos_id)
                or len(req.generated) >= req.max_new_tokens
                or self.slot_pos[s] >= self.max_seq
            ):
                self._finish_slot(s)
                break
        return emitted

    def _commit(
        self, slots_sel: list[int], nxt: np.ndarray,
        bad: frozenset[int] = frozenset(),
    ) -> int:
        """Commit this dispatch's tokens.  `bad` slots (non-finite logits)
        finish with status "error" and emit nothing; a cancel that landed
        while the dispatch was in flight is honoured HERE — the request
        never sees a token sampled after its cancel."""
        emitted = 0
        for s in slots_sel:
            if self.slot_req[s] is None:
                continue
            if s in bad:
                self._finish_slot(
                    s, status="error",
                    error="non-finite logits (guard tripped)",
                )
                continue
            if self.slot_req[s].cancel_requested:
                self._finish_slot(
                    s, status="cancelled", error="cancelled mid-dispatch"
                )
                continue
            emitted += self._commit_tokens(s, [int(nxt[s, 0])])
        return emitted

    # ---- speculative decode (prompt-lookup draft + batched verify) ---------

    def _last_tokens(self, active: list[int]) -> np.ndarray:
        last_tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            last_tokens[s, 0] = req.generated[-1] if req.generated else int(req.prompt[-1])
        return last_tokens

    def _sample_args(self, active: list[int]):
        """(key, temp) extras for sample="temperature" decode dispatches —
        one fresh key per dispatch, per-slot temperature from the request."""
        key = jax.random.fold_in(self._base_key, self._step_idx)
        self._step_idx += 1
        temp = np.zeros(self.slots, np.float32)
        for s in active:
            temp[s] = self.slot_req[s].temperature
        return key, jnp.asarray(temp)

    def _refresh_tables(self) -> None:
        if self.cache_mode == "paged" and self._tables_dirty:
            # Thread the (host-maintained) block tables into the cache
            # leaves; the decode dispatch gathers K/V pages by table.
            # Unchanged tables flow through the donated decode call, so
            # steady-state steps skip the host->device refresh.
            self.caches = self._with_tables(self.caches)
            self._tables_dirty = False

    def _plan_drafts(self, active: list[int], k_max: int | None = None):
        """(L, {slot: draft}) for this step's verify window, or None to take
        the plain one-token path (no headroom, or nothing to propose).
        `k_max` caps drafts below draft_k (the token-budget mixed step
        shares its budget between drafts and prefill chunks)."""
        # One shared window length L: every row's last verify write lands at
        # pos-1 + L-1, which must stay inside max_seq even for padded rows
        # (pads scatter real cache writes), so the most constrained slot caps
        # the batch.  Compiled verify shapes stay O(draft_k) distinct.
        k = self.draft_k if k_max is None else min(self.draft_k, int(k_max))
        head = min(self.max_seq - int(self.slot_pos[s]) + 1 for s in active)
        L = min(1 + k, head)
        if L <= 1:
            return None
        drafts: dict[int, np.ndarray] = {}
        any_draft = False
        for s in active:
            req = self.slot_req[s]
            # A commit is at most (accepted drafts + 1 bonus) tokens — never
            # draft past the request's remaining budget.
            room = req.max_new_tokens - len(req.generated) - 1
            kk = min(L - 1, max(room, 0))
            d = spec_lib._EMPTY
            if kk > 0:
                ctx = np.concatenate([
                    np.asarray(req.prompt, np.int32),
                    np.asarray(req.generated, np.int32),
                ])
                d = np.asarray(self.drafter(ctx, kk), np.int32).ravel()[:kk]
            drafts[s] = d
            any_draft = any_draft or d.size > 0
        return (L, drafts) if any_draft else None

    def _draft_pages_fit(self, active: list[int], L: int) -> bool:
        """True when every active slot's draft window (positions through
        pos-1 + L-1) fits the free pool as-is.  Speculation is an
        optimization: it must NEVER preempt a live request to fund pages
        that only unverified drafts need — when the window doesn't fit, the
        step falls back to plain one-token decode (which allocates at most
        the baseline growth page and may legitimately preempt for that).
        `available()` counts free plus EVICTABLE cached pages: funding a
        draft window may drain cold prefix cache, but never live requests —
        the same eviction-before-preemption ordering the radix cache keeps
        everywhere (docs/ROBUSTNESS.md §Eviction vs preemption)."""
        need = 0
        for s in active:
            pos = max(int(self.slot_pos[s]) - 1, 0) + L - 1
            need += max(0, pos // self.block_size + 1 - len(self.slot_pages[s]))
        return need <= self.alloc.available()

    def _truncate_slot_pages(self, s: int) -> None:
        """Spec-decode rollback: return the pages only rejected drafts
        touched.  The committed history plus the next write position
        (slot_pos - 1) define what the slot still needs; trailing pages go
        back to the pool and their table entries back to scratch.  The stale
        draft K/V inside KEPT pages needs no scrubbing — the decode mask
        (slot <= pos) hides it until a later write replaces it.

        Rollback never frees tree-cached content: draft pages are trailing
        DECODE growth, past the prompt's immutable blocks, so none of them
        can be registered in the radix tree (commit_prompt only registers
        blocks j < shareable_blocks(plen)).  The assert keeps that contract
        explicit — serving/spec.py documents the other half."""
        need = (int(self.slot_pos[s]) - 1) // self.block_size + 1
        extra = self.slot_pages[s][need:]
        if not extra:
            return
        assert not any(self.alloc.is_registered(p) for p in extra), (
            "spec rollback would free radix-registered pages"
        )
        self.slot_pages[s] = self.slot_pages[s][:need]
        req = self.slot_req[s]
        self.alloc.free_pages(
            extra, owner=s,
            tenant=req.tenant if req is not None else paged_lib.DEFAULT_TENANT,
        )
        self.block_table[s, need:] = paged_lib.SCRATCH_PAGE
        self._tables_dirty = True

    def _spec_step(self, active: list[int], L: int, drafts: dict) -> int:
        """ONE batched verify dispatch scores every slot's draft window;
        commit each slot's longest greedy-consistent prefix + bonus token."""
        mat = np.zeros((self.slots, L), np.int32)
        mat[:, :1] = self._last_tokens(active)
        for s in active:
            mat[s, 1 : 1 + drafts[s].size] = drafts[s]
        pos_vec = np.maximum(self.slot_pos.astype(np.int32) - 1, 0)
        logits, self.caches = self._dispatch(
            "verify", "verify_fn",
            self.params, self.caches, jnp.asarray(mat), jnp.asarray(pos_vec),
        )
        bad = self._guard_slots(logits, active)
        # tgt[s, j]: the model's greedy token AFTER consuming mat[s, :j+1] —
        # the acceptance target for draft j and the bonus token at the cut.
        tgt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        st = self.spec_stats
        st["steps"] += 1
        emitted = 0
        for s in active:
            if s in bad:
                self._finish_slot(
                    s, status="error",
                    error="non-finite logits (guard tripped, verify)",
                )
                continue
            if self.slot_req[s].cancel_requested:
                # The cancel landed while the draft window was in flight: no
                # token from this verify is ever emitted; the slot's pages
                # (draft positions included) free through _finish_slot.
                self._finish_slot(
                    s, status="cancelled", error="cancelled mid-dispatch"
                )
                continue
            d = drafts[s]
            a = 0
            while a < d.size and int(d[a]) == int(tgt[s, a]):
                a += 1
            commit = [int(t) for t in d[:a]] + [int(tgt[s, a])]
            req = self.slot_req[s]
            got = self._commit_tokens(s, commit)
            # A finish condition inside the window (EOS among the accepted
            # drafts, max_new_tokens, max_seq) truncates the commit.  The
            # draft tail past the cut was scored but never influenced
            # output — counting it inflated draft_proposed and skewed
            # acceptance_rate low on EOS-heavy workloads.  Count only the
            # drafts actually consumed: on truncation every emitted token
            # IS an accepted draft (the bonus never lands), so proposed ==
            # accepted == got for that row.
            if got == len(commit):
                scored, used = int(d.size), a
            else:
                scored = used = min(got, a)
            req.draft_proposed += scored
            req.draft_accepted += used
            self.slot_proposed[s] += scored
            self.slot_accepted[s] += used
            st["slot_steps"] += 1
            st["proposed"] += scored
            st["accepted"] += used
            st["committed"] += got
            emitted += got
            if self.cache_mode == "paged" and self.slot_req[s] is not None:
                self._truncate_slot_pages(s)
        return emitted

    # ---- token-budget mixed step (chunked prefill beside decode) -----------

    def _mixed_step(self) -> int:
        """ONE token-budget-bounded decode-phase dispatch for every active
        slot: decode rows spend 1 token each (or their spec-verify window)
        and prefill rows spend a chunk of their remaining prompt — a long
        prompt admitted mid-decode streams into the cache beside the
        decoding slots instead of pausing them (zero decode-stall steps by
        construction; gated in benchmarks/check_regression.py).

        The window generalizes the spec-verify machinery: row r holds
        tokens for positions start_r .. start_r + L - 1, where start_r is
        slot_pos - 1 (decode: the last committed token re-presented) or
        prefill_done (prefill: the next chunk).  The masked-causal window
        mask on top of the full committed history IS chunked-prefill
        masking when the window holds prompt tokens.  Window pads write
        garbage K/V strictly BEYOND every row's real content — masked until
        a later real write lands first (the spec-rollback contract) — and
        the shared width L is head-capped so no pad reaches max_seq, while
        the paged table is widened to cover the window so no pad clamps
        onto committed pages (_live_table_width).  A prefill row's final
        chunk yields its first generated token in the same dispatch: the
        logits at the chunk's last window index are the same computation
        the phase-split path runs as its first decode, so output is
        token-identical to sequential prefill-then-decode."""
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        cont = self.continuous
        decode_rows = [
            s for s in active
            if self.slot_prefill_done[s] >= len(self.slot_req[s].prompt)
        ]
        prefill_rows = [s for s in active if s not in set(decode_rows)]

        # Per-row window start; L is capped so start + L <= max_seq for
        # EVERY row (pads scatter real cache writes).  Live decode rows
        # always have slot_pos <= max_seq - 1 (the finish funnel retires
        # them at max_seq) and prefill rows have done < plen <= max_seq,
        # so head >= 1 and decode rows always fit their 1 real token.
        start = {
            s: (
                max(int(self.slot_pos[s]) - 1, 0)
                if s in set(decode_rows)
                else int(self.slot_prefill_done[s])
            )
            for s in active
        }
        head = min(self.max_seq - start[s] for s in active)

        # Spec drafts for decode rows, capped by the budget's spare share.
        drafts: dict[int, np.ndarray] = {}
        if self.spec_decode and decode_rows:
            k_cap = spec_lib.draft_budget(
                self.draft_k, len(decode_rows), self.token_budget
            )
            plan = (
                self._plan_drafts(decode_rows, k_max=min(k_cap, head - 1))
                if k_cap > 0 and head > 1
                else None
            )
            if plan is not None:
                drafts = {s: d for s, d in plan[1].items() if d.size}
            if drafts and self.cache_mode == "paged":
                # Never preempt a live request for pages only unverified
                # drafts need (the _draft_pages_fit contract, per-row).
                need = sum(
                    max(
                        0,
                        (start[s] + int(drafts[s].size)) // self.block_size
                        + 1 - len(self.slot_pages[s]),
                    )
                    for s in drafts
                )
                if need > self.alloc.available():
                    self.spec_stats["pool_deferred"] += 1
                    drafts = {}

        # Budget split: decode rows first (their windows), prefill chunks
        # take the rest — at least 1 token per prefill row.
        decode_cost = sum(
            1 + int(drafts.get(s, spec_lib._EMPTY).size) for s in decode_rows
        )
        chunks: dict[int, int] = {}
        if prefill_rows:
            remaining = {
                s: len(self.slot_req[s].prompt) - int(self.slot_prefill_done[s])
                for s in prefill_rows
            }
            order = sorted(
                prefill_rows,
                key=lambda s: (
                    self.scheduler.rank(self.slot_req[s]),
                    int(self.slot_ticket[s]) if self.cache_mode == "paged" else s,
                ),
            )
            chunks = self.scheduler.split_chunks(decode_cost, remaining, order)
            chunks = {s: min(c, head) for s, c in chunks.items()}

        # Shared window width, bucketed to a power of two so compiled mixed
        # shapes stay O(log budget) distinct; the head cap still rules
        # (real content never exceeds head, so the min never truncates it).
        width = 1
        for s in decode_rows:
            width = max(width, 1 + int(drafts.get(s, spec_lib._EMPTY).size))
        for s in prefill_rows:
            width = max(width, chunks[s])
        L = min(1 << (width - 1).bit_length(), head)

        if self.cache_mode == "paged":
            ends = {}
            for s in decode_rows:
                ends[s] = start[s] + int(drafts.get(s, spec_lib._EMPTY).size)
            for s in prefill_rows:
                ends[s] = start[s] + chunks[s] - 1  # within the admitted plan
            self._ensure_pages(ends)
            if any(self.slot_req[s] is None for s in active):
                # Pool growth preempted someone mid-plan: replan the whole
                # window against the surviving slots rather than reason
                # about a half-evicted layout.  Bounded by slot count.
                return self._mixed_step()
            self.peak_active = max(self.peak_active, len(active))
            # Widen the table to the window (pad-write safety; see
            # _live_table_width) and refresh if the width bucket moved.
            wb = max((start[s] + L - 1) // self.block_size + 1 for s in active)
            if wb != self._window_blocks:
                self._window_blocks = wb
                self._tables_dirty = True
        self._refresh_tables()

        k_cols = 1 + self.draft_k if self.spec_decode else 1
        mat = np.zeros((self.slots, L), np.int32)
        pos_vec = np.zeros(self.slots, np.int32)
        idx = np.zeros((self.slots, k_cols), np.int32)
        for s in decode_rows:
            req = self.slot_req[s]
            mat[s, 0] = (
                req.generated[-1] if req.generated else int(req.prompt[-1])
            )
            d = drafts.get(s, spec_lib._EMPTY)
            if d.size:
                mat[s, 1 : 1 + d.size] = d
            pos_vec[s] = start[s]
            idx[s] = np.minimum(np.arange(k_cols), L - 1)
        for s in prefill_rows:
            req = self.slot_req[s]
            done, c = int(self.slot_prefill_done[s]), chunks[s]
            mat[s, :c] = np.asarray(req.prompt[done : done + c], np.int32)
            pos_vec[s] = done
            idx[s] = c - 1  # the final chunk's bonus logit; unused otherwise

        self._mixed_m = self.slots * L
        cont["mixed_steps"] += 1
        cont["decode_tokens"] += decode_cost
        cont["prefill_tokens"] += sum(chunks.values())
        logits, self.caches = self._dispatch(
            "mixed", "mixed_fn",
            self.params, self.caches,
            jnp.asarray(mat), jnp.asarray(pos_vec), jnp.asarray(idx),
        )
        bad = self._guard_slots(logits, active)
        # tgt[s, j]: the greedy token after consuming mat[s, :idx[s, j]+1].
        tgt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        st = self.spec_stats if (self.spec_decode and drafts) else None
        if st is not None:
            st["steps"] += 1
        emitted = 0
        decode_emitted = 0
        for s in active:
            if self.slot_req[s] is None:
                continue
            if s in bad:
                self._finish_slot(
                    s, status="error",
                    error="non-finite logits (guard tripped, mixed)",
                )
                continue
            req = self.slot_req[s]
            if req.cancel_requested:
                self._finish_slot(
                    s, status="cancelled", error="cancelled mid-dispatch"
                )
                continue
            if s in chunks:
                # Prefill row: the chunk's K/V landed in cache this dispatch.
                done = int(self.slot_prefill_done[s]) + chunks[s]
                self.slot_prefill_done[s] = done
                self.slot_pos[s] = done
                if self.cache_mode == "paged":
                    # Fully covered prompt blocks are now valid prefix
                    # content for later prefix-sharing admissions — and
                    # retainable in the radix cache once released.
                    self.alloc.mark_written(
                        self.slot_pages[s][: done // self.block_size]
                    )
                if done >= len(req.prompt):
                    # Final chunk: its last window index scored position
                    # plen - 1 — the first decode.  Committing it here keeps
                    # prefill completion and first token in one dispatch.
                    cont["completed_prefills"] += 1
                    got = self._commit_tokens(s, [int(tgt[s, 0])])
                    emitted += got
                continue
            # Decode row: greedy-consistent draft prefix + bonus token
            # (plain decode is the d.size == 0 degenerate: bonus only).
            d = drafts.get(s, spec_lib._EMPTY)
            a = 0
            while a < d.size and int(d[a]) == int(tgt[s, a]):
                a += 1
            commit = [int(t) for t in d[:a]] + [int(tgt[s, a])]
            got = self._commit_tokens(s, commit)
            emitted += got
            decode_emitted += got
            if st is not None:
                # Same truncation-aware accounting as _spec_step.
                if got == len(commit):
                    scored, used = int(d.size), a
                else:
                    scored = used = min(got, a)
                req.draft_proposed += scored
                req.draft_accepted += used
                self.slot_proposed[s] += scored
                self.slot_accepted[s] += used
                st["slot_steps"] += 1
                st["proposed"] += scored
                st["accepted"] += used
                st["committed"] += got
            if self.cache_mode == "paged" and self.slot_req[s] is not None:
                self._truncate_slot_pages(s)
        if decode_rows and decode_emitted == 0 and any(
            self.slot_req[s] is not None for s in decode_rows
        ):
            # A live decode row emitted nothing this step — the stall the
            # token budget exists to prevent (0 by construction; the bench
            # gate pins it).
            cont["decode_stall_steps"] += 1
        return emitted

    # ---- the engine loop ---------------------------------------------------

    def step(self) -> int:
        """One engine iteration: fire fault hooks, reap cancelled/expired
        requests, admit, then ONE decode (or ONE speculative verify)
        dispatch for every active slot — bracketed by the step watchdog
        (exception-safe: a dispatch that raises still records its
        latency)."""
        self.step_count += 1
        self.watchdog.step_start()
        try:
            return self._step_inner()
        finally:
            self.watchdog.step_end()

    def _step_inner(self) -> int:
        if self.hooks is not None:
            self.hooks.on_step_begin(self)
        self._reap_lifecycle()
        self._admit()
        if self.scheduler is not None:
            # Token-budget continuous batching: one mixed dispatch serves
            # decode AND chunked prefill; the phase-split paths below never
            # run for this engine.
            return self._mixed_step()
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        spec_plan = self._plan_drafts(active) if self.spec_decode else None
        if self.cache_mode == "paged":
            if spec_plan is not None and not self._draft_pages_fit(active, spec_plan[0]):
                self.spec_stats["pool_deferred"] += 1
                spec_plan = None
            self._ensure_decode_pages(extra=(spec_plan[0] - 1) if spec_plan else 0)
            # Decode growth may have preempted slots (requests requeued).
            active = [s for s in range(self.slots) if self.slot_req[s] is not None]
            if not active:
                return 0
            self.peak_active = max(self.peak_active, len(active))
            if spec_plan is not None:
                L, drafts = spec_plan
                live = set(active)
                drafts = {s: d for s, d in drafts.items() if s in live}
                spec_plan = (
                    (L, drafts) if any(d.size for d in drafts.values()) else None
                )
        if spec_plan is not None:
            self._refresh_tables()
            return self._spec_step(active, *spec_plan)
        last_tokens = self._last_tokens(active)
        if self.decode_mode == "vectorized":
            self._refresh_tables()
            # One dispatch serves all active slots regardless of position skew:
            # each row decodes at its own pos.  Inactive rows decode (and write
            # their cache row at pos 0) with token 0; that write is harmless
            # because every cache position is written before it is attended —
            # the next admission's prefill rewrites the row from position 0 up.
            pos_vec = np.maximum(self.slot_pos.astype(np.int32) - 1, 0)
            args = (
                self.params, self.caches,
                jnp.asarray(last_tokens), jnp.asarray(pos_vec),
            )
            if self.sample == "temperature":
                args = args + self._sample_args(active)
            nxt, logits, self.caches = self._dispatch("decode", "decode_fn", *args)
            bad = self._guard_slots(logits, active)
            return self._commit(active, np.asarray(nxt), bad)
        # Grouped baseline: slots admitted with different prompt lengths decode
        # on their own pos via per-pos grouping; each group's cache rows merge
        # back selectively so other groups' histories stay untouched.
        groups: dict[int, list[int]] = {}
        for s in active:
            groups.setdefault(int(self.slot_pos[s]), []).append(s)
        emitted = 0
        for p, slots in groups.items():
            args = (
                self.params, self.caches,
                jnp.asarray(last_tokens), jnp.asarray(p - 1, jnp.int32),
            )
            if self.sample == "temperature":
                args = args + self._sample_args(slots)
            nxt, logits, new_caches = self._dispatch("decode", "decode_fn", *args)
            self.caches = slot_merge(self.caches, new_caches, slots)
            bad = self._guard_slots(logits, slots)
            emitted += self._commit(slots, np.asarray(nxt), bad)
        return emitted

    def run(self) -> list[Request]:
        while self.queue or any(r is not None for r in self.slot_req):
            self.step()
        return self.finished
