"""Deterministic fault injection for the serving engine — the chaos layer.

The paper's serving value proposition (microkernel-accelerated decode on
constrained hardware) only survives production if the engine degrades
gracefully when the pool is exhausted, a kernel misbehaves, or a client goes
away.  This module makes those events *reproducible*: a `FaultSchedule` is a
seeded, committed list of `Fault`s that fire at exact engine steps, driven
through the injectable hooks `Engine(fault_hooks=...)` exposes — never via
monkeypatching, so the engine under test is byte-for-byte the engine in
production.

Fault taxonomy (docs/ROBUSTNESS.md):

  pool_spike       at step N, seize `pages` free pages from the allocator for
                   `hold` steps — an exhaustion burst (a tenant landing a
                   32k-context job).  Seized pages are accounted: Engine.audit
                   folds `held_pages()` in, so the leak check stays exact.
  kernel_fail      at step N, the next engine dispatch whose resolved
                   registry key matches `key` (fnmatch pattern, e.g.
                   "attn|decode|*") raises KernelFaultError — a simulated
                   kernel crash.  The engine quarantines the key
                   (kernels/registry.demote) and retries on the demoted rung.
  nonfinite_logits at step N, request `uid`'s logit row is overwritten with
                   NaN after the dispatch — a poisoned output.  The engine's
                   finite guard must finish-with-error that slot only; the
                   co-batched rows commit normally.
  nonfinite_kv     at step N, NaN is written into request `uid`'s most recent
                   KV page/row — a poisoned cache.  The slot's *next* logits
                   go non-finite; same guard, one extra step of latency.
  cancel           at step N, request `uid`'s cancel flag is set.  where=
                   "begin" models a client disconnect between steps; "mid"
                   sets the flag after the dispatch launches (a draft window
                   in flight), exercising the commit-time cancel check.
  clock_skew       at step N, the schedule's clock jumps `skew_s` seconds
                   forward — deadline expiry and watchdog stall detection
                   under NTP-step/suspend conditions.  Engines built with
                   `clock=schedule.clock` see the skew; others only see its
                   effect on the schedule's own bookkeeping.

Schedules round-trip through JSON (`to_json`/`from_json`); the committed
adversarial schedules live in tests/fault_schedules/ and are replayed by the
chaos-conformance harness (tests/test_chaos.py) and the `chaos` bench section
(benchmarks/table2_throughput.py).  `FaultSchedule.random(seed, ...)`
generates new ones — by construction only from this taxonomy, so a schedule
that finds a new failure mode can be committed verbatim.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json
import time
from typing import Callable

import numpy as np

FAULT_KINDS = (
    "pool_spike",
    "kernel_fail",
    "nonfinite_logits",
    "nonfinite_kv",
    "cancel",
    "clock_skew",
)


class KernelFaultError(RuntimeError):
    """A (simulated or real) kernel dispatch failure, tagged with the registry
    key the engine should quarantine.  `shard` attributes the fault to one
    tensor-parallel shard (a single bad device/core): the engine then demotes
    only that shard's quarantine entry (kernels/registry.demote(shard=...))
    instead of the key globally.  shard=None (the default, and always the
    case at mesh=1) keeps the global demotion."""

    def __init__(self, key: str, message: str = "injected kernel fault",
                 *, shard: int | None = None):
        suffix = f" (shard {shard})" if shard is not None else ""
        super().__init__(f"{message}: {key}{suffix}")
        self.key = key
        self.shard = shard


@dataclasses.dataclass
class Fault:
    """One injection.  Only the fields its `kind` reads are meaningful."""

    step: int
    kind: str
    uid: int | None = None       # cancel / nonfinite_*: target request
    key: str | None = None       # kernel_fail: registry-key fnmatch pattern
    pages: int = 0               # pool_spike: pages to seize
    hold: int = 1                # pool_spike: steps to hold them
    skew_s: float = 0.0          # clock_skew: seconds to jump forward
    where: str = "begin"         # cancel: "begin" (step boundary) | "mid"
    shard: int | None = None     # kernel_fail: TP shard the fault is local to

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(known: {FAULT_KINDS})")

    def to_dict(self) -> dict:
        out = {"step": self.step, "kind": self.kind}
        defaults = {f.name: f.default for f in dataclasses.fields(Fault)}
        for name in ("uid", "key", "pages", "hold", "skew_s", "where", "shard"):
            val = getattr(self, name)
            if val != defaults[name]:
                out[name] = val
        return out


class FaultSchedule:
    """A deterministic fault plan + the engine-hook implementation that fires
    it.  Pass one instance as `Engine(fault_hooks=schedule)`; drive the engine
    normally.  The schedule keeps its own step counter (one `on_step_begin`
    per engine step), an injection log (`log`), and the pages it is currently
    holding (`held`), which Engine.audit folds into the leak check."""

    def __init__(self, faults: list[Fault], *, seed: int = 0):
        self.faults = sorted(faults, key=lambda f: (f.step, f.kind))
        self.seed = seed
        self.step = -1            # becomes 0 on the first on_step_begin
        self.held: list[tuple[int, list[int]]] = []  # (release_step, pages)
        self.log: list[dict] = []
        self._skew_s = 0.0
        self._base_clock: Callable[[], float] = time.monotonic
        # kernel_fail faults armed for the current step (consumed on fire).
        self._armed_kernel: list[Fault] = []
        self._mid_cancels: list[Fault] = []

    # -- construction / persistence ------------------------------------------

    @classmethod
    def from_dicts(cls, dicts: list[dict], *, seed: int = 0) -> "FaultSchedule":
        return cls([Fault(**d) for d in dicts], seed=seed)

    @classmethod
    def from_json(cls, path: str) -> "FaultSchedule":
        with open(path) as f:
            raw = json.load(f)
        return cls.from_dicts(raw.get("faults", []), seed=int(raw.get("seed", 0)))

    def to_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(
                {"seed": self.seed, "faults": [x.to_dict() for x in self.faults]},
                f, indent=2,
            )
            f.write("\n")
        return path

    @classmethod
    def random(
        cls,
        seed: int,
        *,
        steps: int,
        uids: list[int],
        kinds: tuple[str, ...] = FAULT_KINDS,
        n_faults: int = 6,
        key_pattern: str = "attn|decode|*",
    ) -> "FaultSchedule":
        """Seeded adversarial schedule over the given step/uid ranges — the
        generator the committed schedules came from."""
        rng = np.random.RandomState(seed)
        faults = []
        for _ in range(n_faults):
            kind = kinds[int(rng.randint(len(kinds)))]
            step = int(rng.randint(1, max(2, steps)))
            if kind == "pool_spike":
                faults.append(Fault(step, kind, pages=int(rng.randint(1, 4)),
                                    hold=int(rng.randint(1, 4))))
            elif kind == "kernel_fail":
                faults.append(Fault(step, kind, key=key_pattern))
            elif kind in ("nonfinite_logits", "nonfinite_kv", "cancel"):
                uid = int(uids[int(rng.randint(len(uids)))])
                where = "mid" if kind == "cancel" and rng.rand() < 0.5 else "begin"
                faults.append(Fault(step, kind, uid=uid, where=where))
            else:  # clock_skew
                faults.append(Fault(step, kind, skew_s=float(rng.uniform(0.5, 5.0))))
        return cls(faults, seed=seed)

    # -- the injectable clock -------------------------------------------------

    def clock(self) -> float:
        """Monotonic clock plus every clock_skew fired so far.  Build the
        engine with `clock=schedule.clock` so deadlines and the watchdog see
        the skew."""
        return self._base_clock() + self._skew_s

    # -- engine hooks ---------------------------------------------------------

    def _find_request(self, engine, uid: int):
        """(slot_or_None, request_or_None) for a uid still in flight."""
        for s, req in enumerate(engine.slot_req):
            if req is not None and req.uid == uid:
                return s, req
        for req in engine.queue:
            if req.uid == uid:
                return None, req
        return None, None

    def on_step_begin(self, engine) -> None:
        """Called once at the top of every Engine.step, before admission."""
        self.step += 1
        # Release expired pool seizures first: even a livelocked engine
        # (nothing admissible while pages are held) keeps stepping, so the
        # release below is what bounds every pool_spike's blast radius.
        still = []
        for release_step, pages in self.held:
            if self.step >= release_step:
                engine.alloc.free_pages(pages)
                self.log.append({"step": self.step, "kind": "pool_release",
                                 "pages": len(pages)})
            else:
                still.append((release_step, pages))
        self.held = still
        self._armed_kernel = []
        self._mid_cancels = []
        for fault in self.faults:
            if fault.step != self.step:
                continue
            if fault.kind == "pool_spike" and engine.cache_mode == "paged":
                got = []
                for _ in range(fault.pages):
                    page = engine.alloc.alloc()
                    if page is None:
                        break
                    got.append(page)
                if got:
                    self.held.append((self.step + max(1, fault.hold), got))
                self.log.append({"step": self.step, "kind": fault.kind,
                                 "pages": len(got), "hold": fault.hold})
            elif fault.kind == "kernel_fail":
                self._armed_kernel.append(fault)
            elif fault.kind == "cancel":
                if fault.where == "mid":
                    self._mid_cancels.append(fault)
                else:
                    _, req = self._find_request(engine, fault.uid)
                    if req is not None:
                        req.cancel()
                        self.log.append({"step": self.step, "kind": fault.kind,
                                         "uid": fault.uid, "where": "begin"})
            elif fault.kind == "nonfinite_kv":
                slot, req = self._find_request(engine, fault.uid)
                if slot is not None:
                    engine.poison_slot_kv(slot)
                    self.log.append({"step": self.step, "kind": fault.kind,
                                     "uid": fault.uid, "slot": slot})
            elif fault.kind == "clock_skew":
                self._skew_s += fault.skew_s
                self.log.append({"step": self.step, "kind": fault.kind,
                                 "skew_s": fault.skew_s})
            # nonfinite_logits fires in corrupt_slots (post-dispatch).

    def pre_dispatch(self, engine, kind: str, keys: tuple[str, ...]) -> None:
        """Called immediately before each jitted dispatch (kind: "prefill" |
        "decode" | "verify"; keys: the registry keys the dispatch resolves
        through).  Raises KernelFaultError to simulate a kernel crash; also
        lands "mid" cancels so the flag is set while the window is in
        flight."""
        for fault in self._mid_cancels:
            _, req = self._find_request(engine, fault.uid)
            if req is not None and not req.cancel_requested:
                req.cancel()
                self.log.append({"step": self.step, "kind": "cancel",
                                 "uid": fault.uid, "where": "mid",
                                 "dispatch": kind})
        for fault in list(self._armed_kernel):
            for key in keys:
                if fnmatch.fnmatch(key, fault.key or "*"):
                    self._armed_kernel.remove(fault)
                    entry = {"step": self.step, "kind": "kernel_fail",
                             "key": key, "dispatch": kind}
                    if fault.shard is not None:
                        entry["shard"] = fault.shard
                    self.log.append(entry)
                    raise KernelFaultError(key, shard=fault.shard)

    def corrupt_slots(self, engine, active: list[int]) -> list[int]:
        """Called after a decode/verify dispatch with the active slot list;
        returns the slots whose logits this step's nonfinite_logits faults
        poison.  The engine NaNs those rows before its finite guard runs, so
        the guard is exercised on real non-finite data."""
        out = []
        for fault in self.faults:
            if fault.step != self.step or fault.kind != "nonfinite_logits":
                continue
            slot, _ = self._find_request(engine, fault.uid)
            if slot is not None and slot in active:
                out.append(slot)
                self.log.append({"step": self.step, "kind": fault.kind,
                                 "uid": fault.uid, "slot": slot})
        return out

    def held_pages(self) -> list[int]:
        """Pages currently seized by pool_spike faults — Engine.audit counts
        them as referenced so the exact-leak check keeps holding."""
        return [p for _, pages in self.held for p in pages]

    def drain(self, engine) -> None:
        """Return any still-held pages (schedules that outlive the stream)."""
        for _, pages in self.held:
            engine.alloc.free_pages(pages)
        self.held = []
