"""EngineConfig — the serving engine's consolidated, validated configuration.

`Engine.__init__` historically grew ~15 ad-hoc keyword arguments (cache mode,
paging geometry, speculative decode, token budget, SLO aging, sampling, ...),
each validated and cross-downgraded inline in the constructor.  This module
pulls all of that into one frozen dataclass:

  * construction-time validation (`__post_init__`) — bad values fail at the
    config, not three layers into engine setup;
  * `resolve(model_cfg)` — the cross-field auto-downgrade rules (paged->dense
    for sliding-window models, spec-off-under-sampling, grouped decode for
    recurrent families, ...) applied against a concrete model config,
    returning a NEW config whose fields are what the engine will actually
    run, with every applied rule recorded in `downgrades`;
  * `from_args(namespace)` — argparse routing for launch/serve.py;
  * the tensor-parallel fields `mesh_shape` / `tp_axis` for sharded serving
    over a jax device mesh (launch/mesh.build_serving_mesh).

Engine keeps a deprecation shim — `Engine(params, cfg, enc, slots=8, ...)`
still works and is folded into `EngineConfig(**kwargs)` — but new call sites
should build the config explicitly:

    cfg = EngineConfig(slots=8, token_budget=64, mesh_shape=(2,))
    eng = Engine(params, model_cfg, enc, config=cfg)

Callables (drafter, clock, fault_hooks, stream_cb) are runtime wiring, not
configuration: they stay keyword arguments on Engine and never enter the
frozen config (a config must stay hashable/serializable/comparable).
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.encoding import KV_QUANTS

SAMPLE_MODES = ("greedy", "temperature")
DECODE_MODES = ("vectorized", "grouped")
CACHE_MODES = ("paged", "dense")

# The weight/cache sharding rules in parallel/sharding.py are keyed to the
# mesh axis literally named "model"; a differently-named TP axis would
# silently shard nothing.
TP_AXIS_NAMES = ("model",)


def _attn_only(model_cfg) -> bool:
    return all(t == "attn" for t in model_cfg.block_pattern)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Frozen serving-engine configuration.  Field semantics match the
    long-standing Engine kwargs (serving/engine.py class docstring);
    `mesh_shape`/`tp_axis` are the tensor-parallel additions."""

    slots: int = 4
    max_seq: int = 256
    decode_mode: str = "vectorized"
    batch_prefill: bool = True
    cache_mode: str = "paged"
    block_size: int = 16
    pool_pages: int | None = None
    # KV-cache storage layout: "bf16" (raw), "kv8" (int8 + per-page scales),
    # "kv4" (packed int4 + per-page scales).  Quantized layouts require the
    # paged cache (scale pages ride the block table); resolve() downgrades
    # to bf16 whenever cache_mode lands on dense, and Engine further
    # downgrades kv4 -> kv8 when the attention backend cannot dequantize
    # packed nibbles in-kernel (xla/reference fallbacks).
    kv_quant: str = "bf16"
    # Radix-tree prefix cache (docs/PERF.md §Prefix caching): finished
    # requests park their immutable full KV blocks in a tree keyed by token
    # blocks; later admissions reuse the longest-common-prefix run and
    # prefill only the suffix.  Cached (refcount-0) pages are reclaimed by
    # refcount-aware LRU eviction only when alloc() would otherwise fail,
    # so the flag trades zero steady-state memory for cross-request reuse.
    # Paged-cache only; the dense engine ignores it.
    prefix_cache: bool = True
    # Per-tenant page quota (None = unlimited): an upper bound on the
    # worst-case page reservation any one tenant may hold across its
    # admitted requests, so one tenant's long-context jobs cannot starve
    # the pool (docs/PERF.md §Prefix caching — tenant quotas).
    tenant_quota: int | None = None
    sample: str = "greedy"
    seed: int = 0
    spec_decode: bool = False
    draft_k: int = 4
    max_queue: int | None = None
    logits_guard: bool = True
    token_budget: int | None = None
    slo_aging_steps: int = 64
    # ---- tensor parallelism (docs/PERF.md §Tensor-parallel capacity) -------
    # Device-mesh shape for sharded serving: (1,) = single device (the
    # default; nothing is device_put), (2,)/(4,) = 2/4-way tensor parallel.
    # A 2-d shape (d, t) adds a leading "data" axis (replicated serving
    # batch; reserved for data-parallel replicas).  The product must not
    # exceed jax.device_count() — launch/mesh.build_serving_mesh raises a
    # clear error instead of silently running mesh=1.
    mesh_shape: tuple[int, ...] = (1,)
    tp_axis: str = "model"
    # Audit trail of resolve()'s applied auto-downgrade rules, e.g.
    # ("cache_mode:dense(sliding_window)", "spec_decode:off(sample)").
    # Empty on a hand-built config; populated only by resolve().
    downgrades: tuple[str, ...] = ()

    def __post_init__(self):
        if self.decode_mode not in DECODE_MODES:
            raise ValueError(
                f"decode_mode must be one of {DECODE_MODES}, "
                f"got {self.decode_mode!r}"
            )
        if self.cache_mode not in CACHE_MODES:
            raise ValueError(
                f"cache_mode must be one of {CACHE_MODES}, "
                f"got {self.cache_mode!r}"
            )
        if self.sample not in SAMPLE_MODES:
            raise ValueError(
                f"sample must be one of {SAMPLE_MODES}, got {self.sample!r}"
            )
        if self.kv_quant not in KV_QUANTS:
            raise ValueError(
                f"kv_quant must be one of {KV_QUANTS}, got {self.kv_quant!r}"
            )
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {self.max_seq}")
        if self.block_size < 1 or (self.block_size & (self.block_size - 1)):
            raise ValueError(
                f"block_size must be a power of two >= 1, got {self.block_size}"
            )
        if self.pool_pages is not None and self.pool_pages < 2:
            raise ValueError(
                f"pool_pages must be >= 2 (scratch + one page), "
                f"got {self.pool_pages}"
            )
        if self.tenant_quota is not None and self.tenant_quota < 1:
            raise ValueError(
                f"tenant_quota must be >= 1 pages, got {self.tenant_quota}"
            )
        if self.draft_k < 0:
            raise ValueError(f"draft_k must be >= 0, got {self.draft_k}")
        if self.token_budget is not None and self.token_budget < 1:
            raise ValueError(
                f"token_budget must be >= 1, got {self.token_budget}"
            )
        if self.slo_aging_steps < 1:
            raise ValueError(
                f"slo_aging_steps must be >= 1, got {self.slo_aging_steps}"
            )
        if self.max_queue is not None and self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {self.max_queue}")
        # mesh_shape arrives as a list from argparse / JSON round trips;
        # freeze it to a tuple so the config stays hashable.
        shape = tuple(int(n) for n in self.mesh_shape)
        if not shape or any(n < 1 for n in shape):
            raise ValueError(
                f"mesh_shape must be a non-empty tuple of positive ints, "
                f"got {self.mesh_shape!r}"
            )
        if len(shape) > 3:
            raise ValueError(
                f"mesh_shape supports at most 3 axes (pod, data, tp), "
                f"got {self.mesh_shape!r}"
            )
        object.__setattr__(self, "mesh_shape", shape)
        if self.tp_shards > 1 and self.tp_axis not in TP_AXIS_NAMES:
            raise ValueError(
                f"tp_axis must be one of {TP_AXIS_NAMES} (the sharding rules "
                f"in parallel/sharding.py are keyed to the axis name), "
                f"got {self.tp_axis!r}"
            )
        object.__setattr__(self, "downgrades", tuple(self.downgrades))

    # ---- derived -----------------------------------------------------------

    @property
    def tp_shards(self) -> int:
        """Tensor-parallel degree: the trailing mesh axis (leading axes are
        data/pod replicas)."""
        return int(self.mesh_shape[-1])

    @property
    def mesh_devices(self) -> int:
        return int(math.prod(self.mesh_shape))

    # ---- cross-field auto-downgrade ----------------------------------------

    def resolve(self, model_cfg) -> "EngineConfig":
        """Apply the cross-field downgrade rules against `model_cfg` and
        return the configuration the engine will actually run.  Idempotent;
        every applied rule is appended to `downgrades` (surfaced through
        Engine.stats so a silently-degraded deployment is visible)."""
        changes: dict = {}
        notes: list[str] = list(self.downgrades)
        attn_only = _attn_only(model_cfg)
        window = getattr(model_cfg, "sliding_window", 0)

        # Vectorized decode is only sound for attention KV caches, where an
        # inactive row's write lands at a masked position; recurrent state
        # (rec/rwkv) has no position mask, so those families keep grouped.
        decode_mode = self.decode_mode
        if decode_mode == "vectorized" and not attn_only:
            decode_mode = "grouped"
            changes["decode_mode"] = decode_mode
            notes.append("decode_mode:grouped(recurrent_blocks)")

        # Paged KV needs position-masked attention reads and the per-slot
        # pos vector of the vectorized step.
        cache_mode = self.cache_mode
        if cache_mode == "paged" and (
            not attn_only or window != 0 or decode_mode != "vectorized"
        ):
            cache_mode = "dense"
            changes["cache_mode"] = cache_mode
            why = (
                "recurrent_blocks" if not attn_only
                else "sliding_window" if window != 0
                else "grouped_decode"
            )
            notes.append(f"cache_mode:dense({why})")

        # Quantized KV layouts live in the paged pool (per-page scale
        # storage rides the block table); the dense cache stays raw bf16.
        if self.kv_quant != "bf16" and cache_mode != "paged":
            changes["kv_quant"] = "bf16"
            notes.append("kv_quant:bf16(dense_cache)")

        # Speculation needs greedy-exact acceptance and the masked verify
        # window; sampling has no greedy target, so it switches spec off.
        spec_ok = (
            attn_only and window == 0 and decode_mode == "vectorized"
            and self.sample == "greedy" and self.draft_k > 0
        )
        if self.spec_decode and not spec_ok:
            changes["spec_decode"] = False
            why = (
                "sample" if self.sample != "greedy"
                else "draft_k" if self.draft_k <= 0
                else "model_family"
            )
            notes.append(f"spec_decode:off({why})")

        # The token-budget mixed window rides the same verify machinery.
        budget_ok = (
            attn_only and window == 0 and decode_mode == "vectorized"
            and self.sample == "greedy"
        )
        if self.token_budget is not None and not budget_ok:
            changes["token_budget"] = None
            notes.append("token_budget:off(needs_verify_window)")

        # Batched prefill right-pads; recurrent state and ring-buffer caches
        # would absorb the pad garbage.
        if self.batch_prefill and not (attn_only and window == 0):
            changes["batch_prefill"] = False
            notes.append("batch_prefill:off(model_family)")

        if not changes and tuple(notes) == self.downgrades:
            return self
        return dataclasses.replace(self, downgrades=tuple(notes), **changes)

    # ---- argparse routing (launch/serve.py) --------------------------------

    @classmethod
    def from_args(cls, args) -> "EngineConfig":
        """Build a config from an argparse namespace, mapping any attribute
        that names a config field (missing attributes keep their default).
        `mesh_shape` additionally accepts the CLI string forms "2" and
        "2x4"."""
        kwargs = {}
        for f in dataclasses.fields(cls):
            if f.name == "downgrades" or not hasattr(args, f.name):
                continue
            kwargs[f.name] = getattr(args, f.name)
        shape = kwargs.get("mesh_shape")
        if isinstance(shape, str):
            kwargs["mesh_shape"] = tuple(
                int(p) for p in shape.replace(",", "x").split("x") if p
            )
        return cls(**kwargs)
