"""Deterministic synthetic data pipeline with per-host sharding.

Real multi-pod training reads per-host shards of a tokenized corpus; here the
"corpus" is a seeded synthetic token stream (documents of random length from a
Zipfian vocab with a learnable bigram structure so the loss actually falls).
Determinism contract: (seed, host_id, num_hosts, step) fully determines a
batch — restart/elastic-resume replays the identical stream, and no two hosts
overlap.  Documents are packed into fixed-length rows (sequence packing) with
EOS separators; labels are next-token shifted.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 64
    eos_id: int = 0


class SyntheticPacked:
    """Iterator of {'tokens','labels'} with deterministic per-step content."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        # A fixed random bigram table gives the stream learnable structure.
        rng = np.random.RandomState(cfg.seed)
        self._succ = rng.randint(1, cfg.vocab_size, size=(min(cfg.vocab_size, 4096),), dtype=np.int64)

    def _doc(self, rng: np.random.RandomState) -> np.ndarray:
        n = max(2, int(rng.exponential(self.cfg.mean_doc_len)))
        start = rng.randint(1, self.cfg.vocab_size)
        toks = [start]
        t = len(self._succ)
        for _ in range(n - 1):
            nxt = (self._succ[toks[-1] % t] + rng.randint(0, 3)) % self.cfg.vocab_size
            toks.append(max(1, int(nxt)))
        return np.asarray(toks, np.int32)

    def batch(self, step: int) -> dict:
        c = self.cfg
        rows = np.zeros((self.local_batch, c.seq_len + 1), np.int32)
        for r in range(self.local_batch):
            rng = np.random.RandomState(
                (
                    (c.seed * 1_000_003 + step) * 65_537
                    + (self.host_id * self.local_batch + r)
                )
                % (2**32 - 1)
            )
            fill = 0
            while fill < c.seq_len + 1:
                doc = self._doc(rng)
                take = min(len(doc), c.seq_len + 1 - fill)
                rows[r, fill : fill + take] = doc[:take]
                fill += take
                if fill < c.seq_len + 1:
                    rows[r, fill] = c.eos_id
                    fill += 1
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:].astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """One-batch lookahead on a worker thread (hides host data latency)."""

    def __init__(self, it):
        import queue
        import threading

        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._it = iter(it)

        def work():
            for item in self._it:
                self._q.put(item)

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()
