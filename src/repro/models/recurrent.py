"""Recurrent token-mixing layers: RWKV-6 (Finch) and RG-LRU (RecurrentGemma).

TPU adaptation notes (DESIGN.md §2): the reference CUDA kernels for both are
sequential scans.  Here:
  * RG-LRU uses `jax.lax.associative_scan` (log-depth, parallel over time, the
    TPU-native formulation of a linear recurrence).
  * RWKV-6's matrix-valued state uses the chunked linear-attention form:
    parallel (MXU-friendly) within chunks of 16, sequential lax.scan across
    chunks.  Decay ratios are computed in log space and the per-step
    log-decay is clamped to >= -5 so chunk-level cumprod ratios stay in f32
    range.  Decode is the O(1) recurrence.
All projections are PackedLinear (the paper's encoding applies here too).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import packed
from repro.core.encoding import Phase
from repro.models.layers import norm_apply, norm_init

RWKV_CHUNK = 16
_LOG_DECAY_FLOOR = -5.0


# ---------------------------------------------------------------------------
# RWKV-6 time mix + channel mix


def rwkv_init(key, cfg: ModelConfig, enc: packed.EncodingConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    h = d // hd
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 10)
    lora = max(16, d // 32)
    return {
        "ln1": norm_init(cfg),
        "ln2": norm_init(cfg),
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),  # r,k,v,g,w token-shift mixes
        "w0": jnp.zeros((d,), jnp.float32),
        "w_lora_a": 0.01 * jax.random.normal(ks[0], (d, lora), jnp.float32),
        "w_lora_b": 0.01 * jax.random.normal(ks[1], (lora, d), jnp.float32),
        "u": 0.1 * jax.random.normal(ks[2], (h, hd), jnp.float32),  # bonus
        "wr": packed.linear_init(ks[3], d, d, enc=enc, dtype=dt),
        "wk": packed.linear_init(ks[4], d, d, enc=enc, dtype=dt),
        "wv": packed.linear_init(ks[5], d, d, enc=enc, dtype=dt),
        "wg": packed.linear_init(ks[6], d, d, enc=enc, dtype=dt),
        "wo": packed.linear_init(ks[7], d, d, enc=enc, dtype=dt),
        "cm_mu": 0.5 * jnp.ones((2, d), jnp.float32),  # channel-mix r,k
        "cm_wk": packed.linear_init(ks[8], d, f, enc=enc, dtype=dt),
        "cm_wv": packed.linear_init(ks[9], f, d, enc=enc, dtype=dt),
        "cm_wr": packed.linear_init(jax.random.fold_in(ks[9], 1), d, d, enc=enc, dtype=dt),
    }


def rwkv_state_init(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {
        "S": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((batch, d), cfg.activation_dtype),
        "shift_cm": jnp.zeros((batch, d), cfg.activation_dtype),
    }


def _token_shift(x, shift_state):
    """xs[t] = x[t-1]; xs[0] = shift_state."""
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _wkv_chunked(r, k, v, logw, u, state):
    """Chunked RWKV-6 core.

    r,k,v: (B, S, H, hd); logw: (B, S, H, hd) (<=0, clamped); u: (H, hd);
    state: (B, H, hd, hd) with S[b,h,i,j] over (k-dim i, v-dim j).
    Returns (out (B,S,H,hd) f32, new_state).
    """
    b, s, h, hd = r.shape
    c = min(RWKV_CHUNK, s)
    pad = (-s) % c
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // c

    rr = r.reshape(b, nc, c, h, hd).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    kk = k.reshape(b, nc, c, h, hd).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    vv = v.reshape(b, nc, c, h, hd).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    lw = logw.reshape(b, nc, c, h, hd).transpose(1, 0, 3, 2, 4)
    # shapes now (nc, B, H, c, hd)

    tri = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)  # strict lower

    def chunk_step(S, xs):
        rc, kc, vc, lwc = xs  # (B, H, c, hd)
        lam = jnp.cumsum(lwc, axis=2)              # inclusive cumulative log decay
        lam_prev = lam - lwc                        # exclusive (Λ_{t-1})
        lam_end = lam[:, :, -1:, :]                 # Λ_c
        q_t = rc * jnp.exp(lam_prev)                # r_t ⊙ Λ_{t-1}
        k_t = kc * jnp.exp(-lam)                    # k_i / Λ_i
        k_end = kc * jnp.exp(lam_end - lam)         # k_i ⊙ Λ_c/Λ_i
        # Intra-chunk (strictly causal) + diagonal bonus term.
        a = jnp.einsum("bhtd,bhsd->bhts", q_t, k_t) * tri
        intra = jnp.einsum("bhts,bhsv->bhtv", a, vc)
        diag = jnp.einsum("bhtd,bhtd->bht", rc * u[None, :, None, :], kc)
        intra = intra + diag[..., None] * vc
        # Inter-chunk: contribution of the carried state.
        inter = jnp.einsum("bhtd,bhdv->bhtv", q_t, S)
        # State update.
        s_new = S * jnp.exp(lam_end[:, :, 0, :])[..., None] + jnp.einsum(
            "bhsd,bhsv->bhdv", k_end, vc
        )
        return s_new, intra + inter

    state_f, outs = jax.lax.scan(chunk_step, state.astype(jnp.float32), (rr, kk, vv, lw))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nc * c, h, hd)
    return out[:, :s], state_f


def rwkv_apply(params, x, *, cfg: ModelConfig, enc, phase: Phase, state: dict | None):
    """Full RWKV-6 block: x += TM(norm1(x)); x += CM(norm2(x)).

    Token-shift states track the *normed* sub-block inputs, so decode exactly
    continues a prefill.
    """
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    if state is None:
        state = rwkv_state_init(cfg, b)

    # ---- time mix ----
    xn = norm_apply(params["ln1"], x, cfg)
    if phase is Phase.DECODE:
        xs = jnp.broadcast_to(state["shift_tm"][:, None, :].astype(xn.dtype), xn.shape)
    else:
        xs = _token_shift(xn, state["shift_tm"].astype(xn.dtype))
    dx = xs.astype(jnp.float32) - xn.astype(jnp.float32)
    mu = params["mu"]
    mix = lambda i: (xn.astype(jnp.float32) + dx * mu[i]).astype(xn.dtype)
    mr, mk, mv, mg, mw = mix(0), mix(1), mix(2), mix(3), mix(4)

    r = packed.linear_apply(params["wr"], mr, n=d, phase=phase, enc=enc).reshape(b, s, h, hd)
    k = packed.linear_apply(params["wk"], mk, n=d, phase=phase, enc=enc).reshape(b, s, h, hd)
    v = packed.linear_apply(params["wv"], mv, n=d, phase=phase, enc=enc).reshape(b, s, h, hd)
    g = packed.linear_apply(params["wg"], mg, n=d, phase=phase, enc=enc)
    # Data-dependent decay (THE RWKV-6 feature): w = exp(-exp(w0 + lora(mw))).
    lora = jnp.tanh(mw.astype(jnp.float32) @ params["w_lora_a"]) @ params["w_lora_b"]
    log_neg = params["w0"] + lora                     # pre-activation
    logw = -jnp.exp(jnp.clip(log_neg, -20.0, 1.6))    # log decay, <= 0
    logw = jnp.maximum(logw, _LOG_DECAY_FLOOR).reshape(b, s, h, hd)

    if phase is Phase.DECODE:
        rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
        w1 = jnp.exp(logw[:, 0])                       # (B, H, hd)
        kv = jnp.einsum("bhd,bhv->bhdv", kf[:, 0], vf[:, 0])
        out_t = jnp.einsum(
            "bhd,bhdv->bhv", rf[:, 0], state["S"] + params["u"][None, :, :, None] * kv
        )
        s_new = w1[..., None] * state["S"] + kv
        wkv = out_t[:, None].reshape(b, 1, h, hd)
        new_S = s_new
    else:
        wkv, new_S = _wkv_chunked(r, k, v, logw, params["u"], state["S"])
        wkv = wkv.reshape(b, s, h, hd)

    wkv = wkv.reshape(b, s, d).astype(x.dtype)
    wkv = wkv * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    tm_out = packed.linear_apply(params["wo"], wkv, n=d, phase=phase, enc=enc)
    x = x + tm_out

    # ---- channel mix ----
    cn = norm_apply(params["ln2"], x, cfg)
    if phase is Phase.DECODE:
        cs = jnp.broadcast_to(state["shift_cm"][:, None, :].astype(cn.dtype), cn.shape)
    else:
        cs = _token_shift(cn, state["shift_cm"].astype(cn.dtype))
    dxc = cs.astype(jnp.float32) - cn.astype(jnp.float32)
    cmu = params["cm_mu"]
    cr = (cn.astype(jnp.float32) + dxc * cmu[0]).astype(cn.dtype)
    ck = (cn.astype(jnp.float32) + dxc * cmu[1]).astype(cn.dtype)
    gate_r = jax.nn.sigmoid(
        packed.linear_apply(params["cm_wr"], cr, n=d, phase=phase, enc=enc).astype(jnp.float32)
    )
    hidden = packed.linear_apply(params["cm_wk"], ck, n=cfg.d_ff, phase=phase, enc=enc)
    hidden = jnp.square(jax.nn.relu(hidden.astype(jnp.float32))).astype(cn.dtype)
    down = packed.linear_apply(params["cm_wv"], hidden, n=d, phase=phase, enc=enc)
    out = x + (gate_r * down.astype(jnp.float32)).astype(x.dtype)

    new_state = {
        "S": new_S,
        "shift_tm": xn[:, -1].astype(state["shift_tm"].dtype),
        "shift_cm": cn[:, -1].astype(state["shift_cm"].dtype),
    }
    return out, new_state


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)

_RGLRU_C = 8.0


def rglru_init(key, cfg: ModelConfig, enc: packed.EncodingConfig) -> dict:
    d = cfg.d_model
    rw = cfg.rnn_width or d
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 6)
    return {
        "w_in": packed.linear_init(ks[0], d, rw, enc=enc, dtype=dt),
        "w_gate_branch": packed.linear_init(ks[1], d, rw, enc=enc, dtype=dt),
        "conv_w": 0.1 * jax.random.normal(ks[2], (cfg.conv_width, rw), jnp.float32),
        "conv_b": jnp.zeros((rw,), jnp.float32),
        "w_a": packed.linear_init(ks[3], rw, rw, enc=enc, dtype=dt),
        "w_x": packed.linear_init(ks[4], rw, rw, enc=enc, dtype=dt),
        "lam": jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, rw) ** -0.5)),  # softplus^-1 proxy
        "w_out": packed.linear_init(ks[5], rw, d, enc=enc, dtype=dt),
    }


def rglru_state_init(cfg: ModelConfig, batch: int) -> dict:
    rw = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, rw), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, rw), cfg.activation_dtype),
    }


def _causal_conv1d(x, w, b, conv_state):
    """Depthwise causal conv. x: (B, S, C); w: (W, C); state: (B, W-1, C)."""
    width = w.shape[0]
    xx = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(
        xx[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i]
        for i in range(width)
    ) + b
    new_state = xx[:, -(width - 1) :, :] if width > 1 else conv_state
    return out.astype(x.dtype), new_state


def rglru_apply(params, x, *, cfg: ModelConfig, enc, phase: Phase, state: dict | None):
    """Griffin recurrent block: gate branch ⊙ (conv -> RG-LRU) -> out proj."""
    b, s, d = x.shape
    rw = cfg.rnn_width or d
    if state is None:
        state = rglru_state_init(cfg, b)

    gate = packed.linear_apply(params["w_gate_branch"], x, n=rw, phase=phase, enc=enc)
    gate = jax.nn.gelu(gate.astype(jnp.float32))
    xi = packed.linear_apply(params["w_in"], x, n=rw, phase=phase, enc=enc)
    xi, conv_state = _causal_conv1d(xi, params["conv_w"], params["conv_b"], state["conv"])

    ra = jax.nn.sigmoid(
        packed.linear_apply(params["w_a"], xi, n=rw, phase=phase, enc=enc).astype(jnp.float32)
    )
    ri = jax.nn.sigmoid(
        packed.linear_apply(params["w_x"], xi, n=rw, phase=phase, enc=enc).astype(jnp.float32)
    )
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * ra  # (B, S, rw), <= 0
    a = jnp.exp(log_a)
    gated_x = ri * xi.astype(jnp.float32)
    bt = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    if phase is Phase.DECODE:
        h = a[:, 0] * state["h"] + bt[:, 0]
        y = h[:, None, :]
        new_h = h
    else:
        # Parallel linear recurrence: associative scan over time (log-depth).
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (a, bt), axis=1)
        y = b_cum + a_cum * state["h"][:, None, :]
        new_h = y[:, -1, :]

    y = (y * gate).astype(x.dtype)
    out = packed.linear_apply(params["w_out"], y, n=d, phase=phase, enc=enc)
    return out, {"h": new_h, "conv": conv_state}
