"""Block-level dispatch: one init/apply/cache-init triple per block type.

Types:
  attn        pre-norm attention + (MLP | MoE)   [dense, MoE, hybrid-attn slots]
  rec         pre-norm RG-LRU + MLP              [RecurrentGemma]
  rwkv        RWKV-6 time-mix + channel-mix      [RWKV]
  encdec_attn decoder block w/ self + cross attention  [Whisper decoder]
  enc_attn    bidirectional encoder block        [Whisper encoder]

All blocks share the signature
  init(key, cfg, enc) -> params
  apply(params, x, *, cfg, enc, phase, cache, pos, extra) -> (x, new_cache, aux)
so the grouped layer scan in transformer.py stays type-agnostic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import packed
from repro.core.encoding import Phase
from repro.models import layers as L
from repro.models import recurrent as R


def _zero_aux():
    return jnp.zeros((), jnp.float32)


# ---- attn ------------------------------------------------------------------


def attn_block_init(key, cfg: ModelConfig, enc) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.norm_init(cfg),
        "attn": L.attention_init(k1, cfg, enc),
        "ln2": L.norm_init(cfg),
    }
    if cfg.num_experts:
        p["moe"] = L.moe_init(k2, cfg, enc)
    else:
        p["mlp"] = L.mlp_init(k2, cfg, enc)
    return p


def attn_block_apply(params, x, *, cfg, enc, phase, cache, pos, extra=None):
    h, new_cache = L.attention_apply(
        params["attn"],
        L.norm_apply(params["ln1"], x, cfg),
        cfg=cfg,
        enc=enc,
        phase=phase,
        cache=cache,
        pos=pos,
    )
    x = x + h
    y = L.norm_apply(params["ln2"], x, cfg)
    if cfg.num_experts:
        f, aux = L.moe_apply(params["moe"], y, cfg=cfg, enc=enc, phase=phase)
    else:
        f, aux = L.mlp_apply(params["mlp"], y, cfg=cfg, enc=enc, phase=phase), _zero_aux()
    return x + f, new_cache, aux


def attn_cache_init(cfg, batch, max_seq):
    return L.attn_cache_init(cfg, batch, max_seq)


# ---- rec (RG-LRU) ----------------------------------------------------------


def rec_block_init(key, cfg: ModelConfig, enc) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.norm_init(cfg),
        "rglru": R.rglru_init(k1, cfg, enc),
        "ln2": L.norm_init(cfg),
        "mlp": L.mlp_init(k2, cfg, enc),
    }


def rec_block_apply(params, x, *, cfg, enc, phase, cache, pos, extra=None):
    h, new_cache = R.rglru_apply(
        params["rglru"],
        L.norm_apply(params["ln1"], x, cfg),
        cfg=cfg,
        enc=enc,
        phase=phase,
        state=cache,
    )
    x = x + h
    y = L.norm_apply(params["ln2"], x, cfg)
    f = L.mlp_apply(params["mlp"], y, cfg=cfg, enc=enc, phase=phase)
    return x + f, new_cache, _zero_aux()


def rec_cache_init(cfg, batch, max_seq):
    del max_seq
    return R.rglru_state_init(cfg, batch)


# ---- rwkv ------------------------------------------------------------------


def rwkv_block_init(key, cfg: ModelConfig, enc) -> dict:
    return R.rwkv_init(key, cfg, enc)


def rwkv_block_apply(params, x, *, cfg, enc, phase, cache, pos, extra=None):
    out, new_state = R.rwkv_apply(params, x, cfg=cfg, enc=enc, phase=phase, state=cache)
    return out, new_state, _zero_aux()


def rwkv_cache_init(cfg, batch, max_seq):
    del max_seq
    return R.rwkv_state_init(cfg, batch)


# ---- encoder block (bidirectional) ------------------------------------------


def enc_attn_block_init(key, cfg: ModelConfig, enc) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.norm_init(cfg),
        "attn": L.attention_init(k1, cfg, enc),
        "ln2": L.norm_init(cfg),
        "mlp": L.mlp_init(k2, cfg, enc),
    }


def enc_attn_block_apply(params, x, *, cfg, enc, phase, cache, pos, extra=None):
    h, _ = L.attention_apply(
        params["attn"],
        L.norm_apply(params["ln1"], x, cfg),
        cfg=cfg,
        enc=enc,
        phase=Phase.PREFILL if phase is Phase.DECODE else phase,
        cache=None,
        causal=False,
        use_rope=False,
    )
    x = x + h
    y = L.norm_apply(params["ln2"], x, cfg)
    f = L.mlp_apply(params["mlp"], y, cfg=cfg, enc=enc, phase=phase)
    return x + f, cache, _zero_aux()


# ---- decoder block with cross attention (Whisper) ---------------------------


def encdec_block_init(key, cfg: ModelConfig, enc) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.norm_init(cfg),
        "self_attn": L.attention_init(k1, cfg, enc),
        "ln_x": L.norm_init(cfg),
        "cross_attn": L.attention_init(k2, cfg, enc),
        "ln2": L.norm_init(cfg),
        "mlp": L.mlp_init(k3, cfg, enc),
    }


def encdec_block_apply(params, x, *, cfg, enc, phase, cache, pos, extra=None):
    """cache = {"self": kv-cache, "cross_k": (B,Te,KV,D), "cross_v": ...};
    extra = encoder output (B, Te, D) (prefill/train) or None (decode, cached)."""
    h, new_self = L.attention_apply(
        params["self_attn"],
        L.norm_apply(params["ln1"], x, cfg),
        cfg=cfg,
        enc=enc,
        phase=phase,
        cache=None if cache is None else cache["self"],
        pos=pos,
        use_rope=False,
    )
    x = x + h

    xq = L.norm_apply(params["ln_x"], x, cfg)
    if extra is not None:
        # Compute (and cache) cross K/V from encoder states.
        ca, _ = L.attention_apply(
            params["cross_attn"], xq, cfg=cfg, enc=enc,
            phase=Phase.PREFILL if phase is Phase.DECODE else phase,
            kv_src=extra, use_rope=False,
        )
        b = x.shape[0]
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        ck = packed.linear_apply(
            params["cross_attn"]["wk"], extra, n=kvh * hd, phase=Phase.PREFILL, enc=enc
        ).reshape(b, extra.shape[1], kvh, hd)
        cv = packed.linear_apply(
            params["cross_attn"]["wv"], extra, n=kvh * hd, phase=Phase.PREFILL, enc=enc
        ).reshape(b, extra.shape[1], kvh, hd)
        new_cross_k, new_cross_v = ck, cv
    else:
        assert cache is not None
        q = packed.linear_apply(
            params["cross_attn"]["wq"], xq,
            n=cfg.num_heads * cfg.head_dim, phase=phase, enc=enc,
        ).reshape(x.shape[0], x.shape[1], cfg.num_heads, cfg.head_dim)
        te = cache["cross_k"].shape[1]
        ca = L.attention_decode(
            q, cache["cross_k"], cache["cross_v"], pos=jnp.asarray(te - 1), window=0
        )
        ca = ca.reshape(x.shape[0], x.shape[1], cfg.num_heads * cfg.head_dim)
        ca = packed.linear_apply(
            params["cross_attn"]["wo"], ca, n=cfg.d_model, phase=phase, enc=enc
        )
        new_cross_k, new_cross_v = cache["cross_k"], cache["cross_v"]
    x = x + ca

    y = L.norm_apply(params["ln2"], x, cfg)
    f = L.mlp_apply(params["mlp"], y, cfg=cfg, enc=enc, phase=phase)
    new_cache = cache
    if cache is not None:
        new_cache = {"self": new_self, "cross_k": new_cross_k, "cross_v": new_cross_v}
    return x + f, new_cache, _zero_aux()


def encdec_cache_init(cfg, batch, max_seq):
    return {
        "self": L.attn_cache_init(cfg, batch, max_seq),
        "cross_k": jnp.zeros(
            (batch, cfg.frontend_tokens, cfg.num_kv_heads, cfg.head_dim),
            cfg.activation_dtype,
        ),
        "cross_v": jnp.zeros(
            (batch, cfg.frontend_tokens, cfg.num_kv_heads, cfg.head_dim),
            cfg.activation_dtype,
        ),
    }


BLOCKS = {
    "attn": (attn_block_init, attn_block_apply, attn_cache_init),
    "rec": (rec_block_init, rec_block_apply, rec_cache_init),
    "rwkv": (rwkv_block_init, rwkv_block_apply, rwkv_cache_init),
    "enc_attn": (enc_attn_block_init, enc_attn_block_apply, lambda *a: None),
    "encdec_attn": (encdec_block_init, encdec_block_apply, encdec_cache_init),
}
