"""Shared model layers.  Every dense projection routes through PackedLinear,
so the paper's encoding applies uniformly across the zoo (DESIGN.md §4).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import encoding as encoding_lib
from repro.core import packed
from repro.core import targets as targets_lib
from repro.core.encoding import Phase
from repro.kernels import attn as attn_kernels
from repro.kernels import registry as registry_lib
from repro.parallel import constraints

# ---------------------------------------------------------------------------
# Norms


def norm_init(cfg: ModelConfig, dim: int | None = None) -> dict:
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"] + params["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * params["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope_apply(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D), positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Memory-efficient attention (online softmax over KV chunks)


def _chunk_mask(q_pos, k_pos, *, causal: bool, window: int, k_valid):
    """q_pos: (qc,), k_pos: (kc,) global positions; returns (qc, kc) bool."""
    m = jnp.broadcast_to(k_valid[None, :], (q_pos.shape[0], k_pos.shape[0]))
    if causal:
        m = m & (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        m = m & (k_pos[None, :] > q_pos[:, None] - window)
    return m


def attention_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    window: int,
    q_chunk: int,
    kv_chunk: int,
    q_offset: int = 0,
    expand_kv: bool = False,
    causal_bands: int = 1,
    pad_heads_to: int = 0,
    keep_padded_heads: bool = False,
) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D).  Returns (B, Sq, H, D).

    Flash-style two-level chunking: outer scan over query chunks, inner scan
    over KV chunks with running (max, denom, acc) — peak live memory is one
    (q_chunk x kv_chunk) score block per (batch, head), never Sq x Sk.

    Beyond-paper levers (EXPERIMENTS.md §Perf):
      expand_kv    — repeat KV heads to H so both contractions shard over the
                     full TP axis when kv_heads < TP degree (GQA).
      causal_bands — static query bands whose KV scans stop at the band's own
                     diagonal, skipping always-masked upper-triangle chunks.
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    scale = d**-0.5

    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    nq = -(-sq // qc)
    nk = -(-sk // kc)
    q_pad, k_pad = nq * qc - sq, nk * kc - sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))

    h_true = h
    if expand_kv:
        if kv != h:
            k = jnp.repeat(k, h // kv, axis=2)  # kv-major: matches q head order
            v = jnp.repeat(v, h // kv, axis=2)
        if pad_heads_to and h % pad_heads_to:
            hp = h + (-h) % pad_heads_to
            padw = ((0, 0), (0, 0), (0, hp - h), (0, 0))
            q = jnp.pad(q, padw)  # zero q -> uniform softmax -> sliced off below
            k = jnp.pad(k, padw)
            v = jnp.pad(v, padw)
            h = hp
        k = constraints.shard(k, ("data", "pod"), None, "model")
        v = constraints.shard(v, ("data", "pod"), None, "model")
        q = constraints.shard(q, ("data", "pod"), None, "model")
        kv_eff, g = h, 1
    else:
        kv_eff, g = kv, h // kv

    qr = q.reshape(b, nq, qc, kv_eff, g, d)
    kr = k.reshape(b, nk, kc, kv_eff, d)
    vr = v.reshape(b, nk, kc, kv_eff, d)
    k_len = sk

    def q_step(qi, nk_lim):
        qblk = qr[:, qi] * scale  # (B, qc, KV, G, D)
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kblk = kr[:, ki]  # (B, kc, KV, D)
            vblk = vr[:, ki]
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum(
                "bqkgd,bckd->bkgqc", qblk, kblk, preferred_element_type=jnp.float32
            )  # (B, KV, G, qc, kc)
            mask = _chunk_mask(
                q_pos, k_pos, causal=causal, window=window, k_valid=k_pos < k_len
            )
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            # Guard fully-masked rows (no valid keys yet): keep m finite.
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(
                jnp.isfinite(m_run), jnp.exp(m_run - m_safe), 0.0
            )
            l_new = l_run * corr + p.sum(axis=-1)
            pv = jnp.einsum(
                "bkgqc,bckd->bkgqd", p, vblk, preferred_element_type=jnp.float32
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv_eff, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv_eff, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv_eff, g, qc, d), jnp.float32)
        (m_f, l_f, acc_f), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk_lim))
        out = acc_f / jnp.maximum(l_f, 1e-30)[..., None]
        return out  # (B, KV, G, qc, D)

    bands = causal_bands if (causal and window == 0 and q_offset == 0) else 1
    bands = max(1, min(bands, nq))
    if bands == 1:
        outs = jax.lax.map(lambda qi: q_step(qi, nk), jnp.arange(nq))
    else:
        per = -(-nq // bands)
        pieces = []
        for bnd in range(bands):
            lo = bnd * per
            hi = min(nq, lo + per)
            if lo >= hi:
                break
            # KV chunks visible to the last query row of this band.
            nk_lim = min(nk, -(-(hi * qc) // kc))
            pieces.append(
                jax.lax.map(lambda qi: q_step(qi, nk_lim), jnp.arange(lo, hi))
            )
        outs = jnp.concatenate(pieces, axis=0)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, -1, h, d)
    if keep_padded_heads:
        return out[:, :sq].astype(q.dtype)  # (B, Sq, h_padded, D)
    return out[:, :sq, :h_true].astype(q.dtype)


def paged_gather(
    pool: jnp.ndarray, table: jnp.ndarray, *, nb_blocks: int | None = None
) -> jnp.ndarray:
    """Gather a slot-logical dense cache view from a paged pool.

    pool: (P, bs, KV, D) physical pages; table: (B, NB) int32 page ids.
    Returns (B, NB*bs, KV, D) — row b's logical positions in order, exactly
    the dense cache slice the slot would hold (positions past the slot's
    allocated blocks read whatever page the table points at — the decode
    mask `slot <= pos` never attends them).

    `nb_blocks` bounds the gather to the first nb_blocks logical blocks
    (static): short sequences should not pay for empty trailing table
    entries even on this reference/fallback path.  The serving engine
    narrows the table leaf itself to the live page count
    (engine._with_tables), so its fallback gathers are bounded for free;
    the kernel path (kernels/attn.py paged_decode_attention) never
    materializes this view at all."""
    if nb_blocks is not None and nb_blocks < table.shape[1]:
        table = table[:, :nb_blocks]
    b, nb = table.shape
    g = pool[table]  # (B, NB, bs, KV, D)
    return g.reshape(b, nb * pool.shape[1], *pool.shape[2:])


def _masked_softmax(s: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Softmax over the last axis with `valid` masking, safe for rows with
    NO valid entry (all -inf): those rows come back all-zero instead of NaN
    — a padded admission slot must never poison the batch."""
    s = jnp.where(valid, s, -jnp.inf)
    m = s.max(axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe)
    p = jnp.where(valid, p, 0.0)
    return p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)


def attention_decode(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    *,
    pos: jnp.ndarray,
    window: int,
) -> jnp.ndarray:
    """Decode-phase attention against a (ring-buffered) cache.

    q: (B, L, H, D); caches: (B, S_c, KV, D); pos: () shared position, or (B,)
    per-row positions of the FIRST query token (position-vectorized decode:
    every batch row attends its own history length; the caller has already
    written the L new tokens' K/V at slots (pos + j) % S_c).

    L == 1 is the plain one-token decode.  L > 1 is the speculative-decode
    verify window: query j sits at position pos + j and the `slot <= pos + j`
    mask makes the window masked-causal — draft token j attends the committed
    history plus drafts 0..j (their K/V were scattered into the cache by the
    same dispatch before this read), never drafts j+1..L-1.
    """
    b, L, h, d = q.shape
    _, s_c, kvh, _ = k_cache.shape
    # Ring caches hold only the last `window` positions: a draft key at slot
    # (pos+i) % s_c would alias INSIDE an earlier query's age window, so the
    # mask below cannot express causality for L > 1 — reject loudly instead
    # of attending future drafts (spec decode is full-attention only).
    assert L == 1 or window == 0, (
        "multi-token decode (spec-decode verify) requires window == 0; "
        f"got L={L}, window={window}"
    )
    g = h // kvh
    scale = d**-0.5
    qg = q.reshape(b, L, kvh, g, d) * scale
    s = jnp.einsum(
        "blkgd,bskd->blkgs", qg, k_cache, preferred_element_type=jnp.float32
    )
    slot = jnp.arange(s_c)
    pos = jnp.asarray(pos)
    qpos = (pos[:, None] if pos.ndim == 1 else pos) + jnp.arange(L)
    qpos = jnp.atleast_2d(qpos)  # (B, L) vectorized | (1, L) shared-pos
    if window > 0:
        # Ring buffer: slots hold positions qpos-age; valid while age < window
        # and the position exists.  age = (qpos - slot) mod S_c.  Rows still
        # inside their first window (qpos < window — nothing has wrapped or
        # aged out) reduce exactly to the cheap prefix mask: slot s holds
        # position s, age = qpos - s >= 0 and < window iff s <= qpos.  Only
        # wrapped rows pay the mod.
        age = jnp.mod(qpos[..., None] - slot, s_c)
        ring = age < jnp.minimum(qpos[..., None] + 1, window)
        valid = jnp.where(qpos[..., None] < window, slot <= qpos[..., None], ring)
    else:
        valid = slot <= qpos[..., None]
    # valid: (B, L, S_c) vectorized, (1, L, S_c) shared-pos.  The guarded
    # softmax keeps fully-masked rows (padded admission slots) at zero
    # instead of NaN.
    p = _masked_softmax(s, valid[:, :, None, None, :])
    out = jnp.einsum(
        "blkgs,bskd->blkgd", p, v_cache, preferred_element_type=jnp.float32
    )
    return out.reshape(b, L, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + cache plumbing)


def attention_init(key, cfg: ModelConfig, enc: packed.EncodingConfig, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    dt = cfg.activation_dtype
    return {
        "wq": packed.linear_init(ks[0], d, h * hd, enc=enc, use_bias=cfg.qkv_bias, dtype=dt),
        "wk": packed.linear_init(ks[1], d, kvh * hd, enc=enc, use_bias=cfg.qkv_bias, dtype=dt),
        "wv": packed.linear_init(ks[2], d, kvh * hd, enc=enc, use_bias=cfg.qkv_bias, dtype=dt),
        "wo": packed.linear_init(ks[3], h * hd, d, enc=enc, dtype=dt),
    }


def attention_apply(
    params: dict,
    x: jnp.ndarray,
    *,
    cfg: ModelConfig,
    enc: packed.EncodingConfig,
    phase: Phase,
    cache: dict | None = None,
    pos: jnp.ndarray | int = 0,
    kv_src: jnp.ndarray | None = None,
    causal: bool = True,
    use_rope: bool = True,
    window: int | None = None,
):
    """Returns (out, new_cache). kv_src != None -> cross attention (no cache write).

    `pos` may be a scalar (all rows share a position — prefill offset or
    uniform decode) or a (B,) vector (position-vectorized decode: each batch
    row carries its own position of x[:, 0]; DECODE only).  At DECODE, S > 1
    is a per-row masked-causal window — row b's S tokens occupy positions
    pos_b .. pos_b+S-1, all S K/V pairs are written, and attention is
    masked-causal inside the window; full attention only (window == 0 —
    attention_decode rejects ring caches for S > 1).  Two callers ride it:
    the speculative-decode verify window, and the token-budget mixed step
    (serving/engine.py), where decode rows carry 1 real token (+ drafts) and
    chunked-prefill rows carry a window of prompt tokens at pos_b = tokens
    already cached — `slot <= pos_b + j` is exactly chunked-prefill masking
    (full history + causal-in-window), so one dispatch serves both phases.
    Window positions past a row's real content (padding to the rectangular
    S) write garbage K/V at FUTURE positions only — masked until a later
    real write lands there first, the same contract rejected spec drafts
    rely on.  The engine caps S so pos_b + S <= max cache length for every
    participating row; the write indexing below still clamps defensively so
    an out-of-contract pad can never scatter outside the row's cache.
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    window = cfg.sliding_window if window is None else window
    pos_vec = jnp.asarray(pos).ndim == 1  # per-row positions

    q = packed.linear_apply(params["wq"], x, n=h * hd, phase=phase, enc=enc)
    kv_in = kv_src if kv_src is not None else x
    k = packed.linear_apply(params["wk"], kv_in, n=kvh * hd, phase=phase, enc=enc)
    v = packed.linear_apply(params["wv"], kv_in, n=kvh * hd, phase=phase, enc=enc)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, kv_in.shape[1], kvh, hd)
    v = v.reshape(b, kv_in.shape[1], kvh, hd)
    if cfg.tp_attn_expand_kv:
        # SP/TP: query heads over the model axis (divisibility-sanitized).
        q = constraints.shard(q, ("data", "pod"), None, "model")

    if use_rope and kv_src is None:
        if pos_vec:
            positions = jnp.asarray(pos)[:, None] + jnp.arange(s)[None, :]
        else:
            positions = pos + jnp.arange(s)[None, :]
            positions = jnp.broadcast_to(positions, (b, s))
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)

    new_cache = cache
    if (
        phase is Phase.DECODE and cache is not None and kv_src is None
        and "table" in cache
    ):
        # Paged KV cache: pool (P, bs, KV, D) + per-slot block table (B, NB).
        # Row b writes token j into page table[b, (pos+j)//bs] at offset
        # (pos+j) % bs (the engine guarantees every written page exists and is
        # private to the slot — shared prefix pages are immutable full
        # blocks), then attends the table-gathered logical view with the SAME
        # per-row `pos` masking as the dense path.  S > 1 is the speculative-
        # decode verify window: all S positions scatter before the gather, so
        # draft keys are visible to later draft queries (masked-causal).
        # Idle rows point at the scratch page.
        assert window == 0, "paged cache excludes sliding-window configs"
        table = cache["table"]
        bs_page = cache["k"].shape[1]
        # The cache pytree is self-describing: int8 pools are kv8, packed
        # uint8 pools are kv4 (core/encoding.KVLayout) — jitted model code
        # never needs the engine config threaded through.
        layout = encoding_lib.kv_layout_for_storage(cache["k"].dtype)
        posv = jnp.asarray(pos)
        posm = (posv[:, None] if posv.ndim == 1 else posv) + jnp.arange(s)
        posm = jnp.broadcast_to(posm, (b, s))
        # Window pads past the last logical block clamp to the final table
        # entry (scratch unless the row's table is full — and the engine
        # caps the window so a full row never pads past max_seq).
        blk = jnp.minimum(posm // bs_page, table.shape[1] - 1)
        pg = table[jnp.arange(b)[:, None], blk]  # (B, S)
        off = posm % bs_page
        if layout.quantized:
            # Quantize on write: the pool only ever holds int storage plus
            # the per-token scale pages riding at the same page ids.
            kq, ksc = layout.quantize(k)
            vq, vsc = layout.quantize(v)
            k_pool = cache["k"].at[pg, off].set(kq)
            v_pool = cache["v"].at[pg, off].set(vq)
            k_scale = cache["k_scale"].at[pg, off].set(ksc)
            v_scale = cache["v_scale"].at[pg, off].set(vsc)
        else:
            k_pool = cache["k"].at[pg, off].set(k)
            v_pool = cache["v"].at[pg, off].set(v)
            k_scale = v_scale = None
        choice = registry_lib.select_attn(
            phase=Phase.DECODE, s=table.shape[1] * bs_page, target=enc.target,
            requested=enc.attn_backend, kv=layout.name,
        )
        if choice.backend == "pallas":
            # Fused paged-decode kernel: K/V pages gathered tile-by-tile
            # INSIDE the dispatch (scalar-prefetched block table), only the
            # slot's live pages streamed — the (B, NB*bs, KV, D) logical
            # view is never materialized.  Quantized layouts stream the
            # scale pages alongside and dequantize tile-locally in VMEM.
            out = attn_kernels.paged_decode_attention(
                q, k_pool, v_pool, table, posm[:, 0],
                k_scale=k_scale, v_scale=v_scale, kv_quant=layout.name,
                interpret=targets_lib.resolve_interpret(enc.interpret),
            )
        elif layout.quantized:
            # XLA fallback: gather the quantized view AND its scale view,
            # dequantize, then run the reference decode — the page stream
            # and the codec stay identical to the kernel path, only the
            # gather materialization differs.
            out = attention_decode(
                q,
                layout.dequantize(
                    paged_gather(k_pool, table), paged_gather(k_scale, table)
                ),
                layout.dequantize(
                    paged_gather(v_pool, table), paged_gather(v_scale, table)
                ),
                pos=pos, window=0,
            )
        else:
            out = attention_decode(
                q, paged_gather(k_pool, table), paged_gather(v_pool, table),
                pos=pos, window=0,
            )
        new_cache = {"k": k_pool, "v": v_pool, "table": table}
        if layout.quantized:
            new_cache["k_scale"] = k_scale
            new_cache["v_scale"] = v_scale
    elif phase is Phase.DECODE and cache is not None and kv_src is None:
        s_c = cache["k"].shape[1]
        if pos_vec:
            # Per-row scatter: row i writes its own S cache slots (one token
            # per position pos_i + j; S > 1 is the spec-decode verify window
            # or a mixed-step prefill chunk, whose beyond-content writes stay
            # masked until overwritten).  Full-attention windows clamp at the
            # cache edge: the engine caps S per row, so a clamped index is
            # only ever a pad colliding with other pads.
            positions = jnp.asarray(pos)[:, None] + jnp.arange(s)  # (B, S)
            wslot = (
                jnp.mod(positions, s_c) if window > 0
                else jnp.minimum(positions, s_c - 1)
            )
            k_cache = cache["k"].at[jnp.arange(b)[:, None], wslot].set(k)
            v_cache = cache["v"].at[jnp.arange(b)[:, None], wslot].set(v)
        else:
            slot = jnp.mod(jnp.asarray(pos), s_c) if window > 0 else jnp.asarray(pos)
            k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
        choice = registry_lib.select_attn(
            phase=Phase.DECODE, s=s_c, target=enc.target,
            requested=enc.attn_backend,
        )
        if choice.backend == "pallas" and (s == 1 or window == 0):
            out = attn_kernels.dense_decode_attention(
                q, k_cache, v_cache, jnp.asarray(pos, jnp.int32),
                window=window,
                kv_chunk=choice.blocks[1] if choice.blocks else None,
                interpret=targets_lib.resolve_interpret(enc.interpret),
            )
        else:
            out = attention_decode(q, k_cache, v_cache, pos=pos, window=window)
    else:
        # When W_o's packed K-padding already covers the padded head count,
        # the padded heads flow straight into the (zero) padding rows of W_o —
        # no slice, no reshard (EXPERIMENTS.md §Perf, qwen iteration 2).
        keep_pad = False
        wo_w = params["wo"].get("w_packed", params["wo"].get("w_q"))
        if cfg.tp_attn_expand_kv and cfg.pad_attn_heads_to and wo_w is not None:
            hp = h + (-h) % cfg.pad_attn_heads_to
            k1_cap = wo_w.shape[1] * wo_w.shape[3]
            keep_pad = hp * hd <= k1_cap
        # Chunked prefill: attend over previously-cached positions too
        # (static pos offset; dense cache only — window ring excluded).
        q_off = 0
        k_att, v_att = k, v
        prior = isinstance(pos, int) and pos > 0 and cache is not None
        if prior and kv_src is None and window == 0:
            k_att = jnp.concatenate([cache["k"][:, :pos], k], axis=1)
            v_att = jnp.concatenate([cache["v"][:, :pos], v], axis=1)
            q_off = pos
        # Flash prefill eligibility: inference-side plain self-attention
        # only — the TP expand_kv reshard and cross attention keep the
        # chunked reference (the kernel has no sharding constraints inside
        # it), and TRAIN needs autodiff through the attention, which the
        # forward-only Pallas kernel does not provide.
        choice = registry_lib.select_attn(
            phase=Phase.PREFILL, s=k_att.shape[1], target=enc.target,
            requested=enc.attn_backend,
        )
        if (
            choice.backend == "pallas"
            and phase is not Phase.TRAIN
            and kv_src is None
            and not cfg.tp_attn_expand_kv
        ):
            qc, kc = (choice.blocks or (cfg.q_chunk, cfg.kv_chunk))[:2]
            out = attn_kernels.flash_prefill_attention(
                q, k_att, v_att,
                causal=causal,
                window=window,
                q_offset=q_off,
                q_chunk=qc,
                kv_chunk=kc,
                interpret=targets_lib.resolve_interpret(enc.interpret),
            )
        else:
            out = attention_chunked(
                q, k_att, v_att,
                causal=causal and kv_src is None,
                window=window,
                q_chunk=cfg.q_chunk,
                kv_chunk=cfg.kv_chunk,
                q_offset=q_off,
                expand_kv=cfg.tp_attn_expand_kv,
                causal_bands=cfg.causal_bands,
                pad_heads_to=cfg.pad_attn_heads_to,
                keep_padded_heads=keep_pad,
            )
        if cache is not None and kv_src is None:
            assert "table" not in cache, (
                "paged caches are decode-only; the engine prefills into a "
                "temporary dense cache and scatters blocks into the pool"
            )
            s_c = cache["k"].shape[1]
            if window > 0 and s >= s_c:
                new_cache = {"k": k[:, -s_c:], "v": v[:, -s_c:]}
            else:
                off = q_off if window == 0 else 0
                k_cache = jax.lax.dynamic_update_slice(cache["k"], k[:, -s_c:], (0, off, 0, 0))
                v_cache = jax.lax.dynamic_update_slice(cache["v"], v[:, -s_c:], (0, off, 0, 0))
                new_cache = {"k": k_cache, "v": v_cache}

    out = out.reshape(b, s, out.shape[2] * hd)
    return packed.linear_apply(params["wo"], out, n=d, phase=phase, enc=enc), new_cache


def attn_cache_init(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    s_c = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    dt = cfg.activation_dtype
    return {
        "k": jnp.zeros((batch, s_c, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, s_c, cfg.num_kv_heads, cfg.head_dim), dt),
    }


def attn_paged_cache_init(
    cfg: ModelConfig, batch: int, max_seq: int, *, block_size: int,
    num_pages: int, kv_quant: str = "bf16",
) -> dict:
    """Paged attention cache: a page pool + per-slot block table, replacing
    the dense (batch, max_seq) reservation.  Page 0 is the scratch page idle
    rows write to (serving/paged.py); tables init to it.

    `kv_quant` selects the KVLayout (core/encoding): bf16 keeps today's
    activation-dtype pools bit-for-bit; kv8/kv4 store int pools (kv4 packs
    two nibbles per byte along head_dim) plus float32 `k_scale`/`v_scale`
    SCALE PAGES with the same (num_pages, block) page geometry — one page
    id addresses a token block's data and its scales together, so
    alloc/free/COW/rollback in serving/paged.py manage both in lockstep."""
    assert cfg.sliding_window == 0, "paged cache excludes sliding-window configs"
    nb = -(-max_seq // block_size)
    layout = encoding_lib.kv_layout(kv_quant)
    dt = cfg.activation_dtype if not layout.quantized else layout.storage_dtype
    hd = (
        cfg.head_dim if not layout.quantized
        else layout.storage_head_dim(cfg.head_dim)
    )
    out = {
        "k": jnp.zeros((num_pages, block_size, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros((num_pages, block_size, cfg.num_kv_heads, hd), dt),
        "table": jnp.zeros((batch, nb), jnp.int32),
    }
    if layout.quantized:
        sshape = layout.scale_shape((num_pages, block_size), cfg.num_kv_heads)
        out["k_scale"] = jnp.zeros(sshape, jnp.float32)
        out["v_scale"] = jnp.zeros(sshape, jnp.float32)
    return out


# ---------------------------------------------------------------------------
# MLP


def mlp_init(key, cfg: ModelConfig, enc: packed.EncodingConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.activation_dtype
    if cfg.mlp_kind == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": packed.linear_init(k1, d, f, enc=enc, dtype=dt),
            "w_up": packed.linear_init(k2, d, f, enc=enc, dtype=dt),
            "w_down": packed.linear_init(k3, f, d, enc=enc, dtype=dt),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_up": packed.linear_init(k1, d, f, enc=enc, use_bias=True, dtype=dt),
        "w_down": packed.linear_init(k2, f, d, enc=enc, use_bias=True, dtype=dt),
    }


def mlp_apply(params, x, *, cfg: ModelConfig, enc, phase: Phase) -> jnp.ndarray:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        gate = packed.linear_apply(params["w_gate"], x, n=f, phase=phase, enc=enc)
        up = packed.linear_apply(params["w_up"], x, n=f, phase=phase, enc=enc)
        hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        up = packed.linear_apply(params["w_up"], x, n=f, phase=phase, enc=enc)
        hidden = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return packed.linear_apply(params["w_down"], hidden, n=d, phase=phase, enc=enc)


# ---------------------------------------------------------------------------
# MoE (token-choice top-k, capacity-bounded scatter dispatch)


def moe_init(key, cfg: ModelConfig, enc: packed.EncodingConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = cfg.activation_dtype
    kr, kg, ku, kd = jax.random.split(key, 4)

    def stack_init(k, din, dout):
        keys = jax.random.split(k, e)
        # Stacked per-expert linear params (works for packed / int8 / plain).
        return jax.vmap(
            lambda kk: packed.linear_init(kk, din, dout, enc=enc, dtype=dt)
        )(keys)

    return {
        "router": packed.linear_init(kr, d, e, enc=enc, dtype=jnp.float32),
        "w_gate": stack_init(kg, d, f),   # dict of (E, ...) leaves
        "w_up": stack_init(ku, d, f),
        "w_down": stack_init(kd, f, d),
    }


def _expert_matmul(w_stack, x, *, n, phase, enc):
    """x: (E, ..., D) batched over experts; w_stack: dict of (E, ...) leaves."""

    def one(w, xe):
        return packed.linear_apply(w, xe, n=n, phase=phase, enc=enc)

    return jax.vmap(one)(w_stack, x)


def _dp_axes_and_size():
    """Ambient-mesh DP axes for shard_map dispatch; (None, 1) when no mesh."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return None, ()
    if am is None or getattr(am, "empty", True):
        return None, ()
    dp = tuple(a for a in ("pod", "data") if a in am.axis_names)
    return am, dp


def moe_apply(params, x, *, cfg: ModelConfig, enc, phase: Phase):
    """Returns (out, aux_loss). Capacity-bounded token-choice top-k routing.

    Beyond-paper §Perf levers:
      cfg.moe_dispatch_groups > 1 — group-local ranking/scatter aligned to the
        DP shards (capacity per group).
      cfg.moe_shard_map — dispatch/combine under shard_map: shard-local by
        construction; expert FFNs remain auto-SPMD (TP-sharded weights).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    f = cfg.d_ff
    t = b * s
    xt = x.reshape(t, d)

    logits = packed.linear_apply(
        params["router"], xt, n=e, phase=phase, enc=enc, out_dtype=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    if cfg.moe_dense_decode and phase is Phase.DECODE:
        # Dispatch-free decode: every expert sees every live token.
        xe = jnp.broadcast_to(xt[None], (e, t, d))
        gate_h = _expert_matmul(params["w_gate"], xe, n=f, phase=phase, enc=enc)
        up_h = _expert_matmul(params["w_up"], xe, n=f, phase=phase, enc=enc)
        hidden = jax.nn.silu(gate_h.astype(jnp.float32)).astype(x.dtype) * up_h
        ys = _expert_matmul(params["w_down"], hidden, n=d, phase=phase, enc=enc)
        # Combine: per-token gate over its top-k experts, zero elsewhere.
        wfull = jnp.zeros((t, e), jnp.float32)
        wfull = wfull.at[jnp.arange(t)[:, None], eidx].set(gate)
        out = jnp.einsum("etd,te->td", ys.astype(jnp.float32), wfull)
        onehot = jax.nn.one_hot(eidx, e, dtype=jnp.float32)
        aux = e * jnp.sum(probs.mean(0) * onehot.sum(1).mean(0))
        return out.astype(x.dtype).reshape(b, s, d), aux

    if cfg.moe_shard_map:
        mesh, dp = _dp_axes_and_size()
        dp_size = 1
        if mesh is not None and dp:
            for a in dp:
                dp_size *= mesh.shape[a]
        if mesh is not None and dp and dp_size > 1 and t % dp_size == 0:
            out, aux = _moe_shard_map_apply(
                params, xt, gate, eidx, probs,
                cfg=cfg, enc=enc, phase=phase, mesh=mesh, dp=dp, dp_size=dp_size,
            )
            return out.reshape(b, s, d), aux

    groups = cfg.moe_dispatch_groups if cfg.moe_dispatch_groups > 1 else 1
    if t % groups:
        groups = 1
    tg = t // groups
    cap = max(1, int(cfg.capacity_factor * tg * k / e))

    # Position of each (token, slot) in its expert queue; slot-major priority,
    # group-local rank when groups > 1.
    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.float32)  # (T, k, E)
    oh_g = onehot.reshape(groups, tg, k, e).transpose(0, 2, 1, 3).reshape(
        groups, k * tg, e
    )  # slot-major within group
    pos_flat = (jnp.cumsum(oh_g, axis=1) - oh_g) * oh_g
    position = (
        pos_flat.sum(-1).reshape(groups, k, tg).transpose(0, 2, 1).astype(jnp.int32)
    )  # (G, tg, k)
    keep = position < cap
    eidx_g = eidx.reshape(groups, tg, k)
    gate_g = gate.reshape(groups, tg, k)
    xt_g = xt.reshape(groups, tg, d)

    # Dispatch: scatter tokens into (G, E, C, D) buffers; groups shard over
    # the data axes (token-parallel side of the EP layout, DESIGN.md §5).
    buf = constraints.shard(
        jnp.zeros((groups, e, cap, d), x.dtype), ("data", "pod")
    )
    safe_pos = jnp.where(keep, position, cap - 1)
    contrib = jnp.where(keep[..., None], 1.0, 0.0).astype(x.dtype)
    gsel = jnp.arange(groups)[:, None, None]
    buf = buf.at[gsel, eidx_g, safe_pos].add(
        xt_g[:, :, None, :] * contrib, mode="drop"
    )
    buf = constraints.shard(buf, ("data", "pod"))

    # Expert FFNs (batched over E; group dim folds into the row dim).
    buf_e = buf.transpose(1, 0, 2, 3)  # (E, G, C, D)
    gate_h = _expert_matmul(params["w_gate"], buf_e, n=f, phase=phase, enc=enc)
    up_h = _expert_matmul(params["w_up"], buf_e, n=f, phase=phase, enc=enc)
    hidden = jax.nn.silu(gate_h.astype(jnp.float32)).astype(x.dtype) * up_h
    ys = _expert_matmul(params["w_down"], hidden, n=d, phase=phase, enc=enc)
    ys = constraints.shard(ys, None, ("data", "pod"))  # (E, G, C, D)

    # Combine: gather back and weight.
    gathered = ys.transpose(1, 0, 2, 3)[gsel, eidx_g, safe_pos]  # (G, tg, k, D)
    w = (gate_g * keep).astype(jnp.float32)[..., None]
    out = (gathered.astype(jnp.float32) * w).sum(axis=2).astype(x.dtype)

    # Load-balance aux loss (Switch-style).
    me = probs.mean(axis=0)
    ce = onehot.sum(axis=1).mean(axis=0)
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux


def _moe_shard_map_apply(params, xt, gate, eidx, probs, *, cfg, enc, phase,
                         mesh, dp, dp_size):
    """shard_map dispatch/combine (see moe_apply docstring)."""
    from jax.sharding import PartitionSpec as P

    e, k, d, f = cfg.num_experts, cfg.experts_per_token, cfg.d_model, cfg.d_ff
    t = xt.shape[0]
    tg = t // dp_size
    cap = max(1, int(cfg.capacity_factor * tg * k / e))

    def dispatch(xt_s, eidx_s):
        # All arrays here are one DP shard's slice: (tg, ...).
        onehot = jax.nn.one_hot(eidx_s, e, dtype=jnp.float32)        # (tg,k,e)
        flat = onehot.transpose(1, 0, 2).reshape(k * tg, e)          # slot-major
        pos = ((jnp.cumsum(flat, 0) - flat) * flat).sum(-1)
        pos = pos.reshape(k, tg).transpose(1, 0).astype(jnp.int32)   # (tg,k)
        keep = pos < cap
        safe = jnp.where(keep, pos, cap - 1)
        contrib = jnp.where(keep[..., None], 1.0, 0.0).astype(xt_s.dtype)
        buf = jnp.zeros((e, cap, d), xt_s.dtype)
        buf = buf.at[eidx_s, safe].add(xt_s[:, None, :] * contrib, mode="drop")
        return buf, safe, keep

    buf, safe_pos, keep = jax.shard_map(
        dispatch, mesh=mesh,
        in_specs=(P(dp), P(dp)),
        out_specs=(P(None, dp), P(dp), P(dp)),
    )(xt, eidx)
    # buf: (E, dp_size*cap, D), capacity sharded over the DP axes.

    gate_h = _expert_matmul(params["w_gate"], buf, n=f, phase=phase, enc=enc)
    up_h = _expert_matmul(params["w_up"], buf, n=f, phase=phase, enc=enc)
    hidden = jax.nn.silu(gate_h.astype(jnp.float32)).astype(xt.dtype) * up_h
    ys = _expert_matmul(params["w_down"], hidden, n=d, phase=phase, enc=enc)
    ys = constraints.shard(ys, None, ("pod", "data"))  # keep capacity on DP

    def combine(ys_s, eidx_s, safe_s, keep_s, gate_s):
        gathered = ys_s[eidx_s, safe_s]  # (tg, k, d) — local capacity slice
        w = (gate_s * keep_s).astype(jnp.float32)[..., None]
        return (gathered.astype(jnp.float32) * w).sum(axis=1).astype(ys_s.dtype)

    out = jax.shard_map(
        combine, mesh=mesh,
        in_specs=(P(None, dp), P(dp), P(dp), P(dp), P(dp)),
        out_specs=P(dp),
    )(ys, eidx, safe_pos, keep, gate)

    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.float32)
    me = probs.mean(axis=0)
    ce = onehot.sum(axis=1).mean(axis=0)
    aux = e * jnp.sum(me * ce)
    return out.astype(xt.dtype), aux
