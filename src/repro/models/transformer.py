"""Full model assembly: embeddings, grouped layer scan, head; all families.

Layer stacking uses a *grouped scan*: the layer list is `block_pattern`
repeated (e.g. ("rec","rec","attn") for RecurrentGemma); full pattern groups
are stacked and driven by one `lax.scan` (small HLO, fast 512-device compiles),
a partial tail group (when num_layers % len(pattern) != 0) is applied inline.
Under Phase.TRAIN each scan body is rematerialized (jax.checkpoint).

Frontends (audio frames / vision patches) are stubs per the assignment: the
caller provides precomputed embeddings; whisper additionally runs its real
encoder stack over the provided frame embeddings.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import packed
from repro.core.encoding import Phase
from repro.models import layers as L
from repro.models.blocks import BLOCKS


# ---------------------------------------------------------------------------
# Layer grouping


def _pattern_layout(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    p = cfg.block_pattern
    return cfg.num_layers // len(p), tuple(p[: cfg.num_layers % len(p)])


def _group_init(key, cfg, enc, pattern):
    parts = []
    for i, t in enumerate(pattern):
        parts.append(BLOCKS[t][0](jax.random.fold_in(key, i), cfg, enc))
    return tuple(parts)


def _stacked_group_init(key, cfg, enc, pattern, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _group_init(k, cfg, enc, pattern))(keys)


def _group_cache_init(cfg, pattern, batch, max_seq):
    return tuple(BLOCKS[t][2](cfg, batch, max_seq) for t in pattern)


def _stack_caches(cache, n):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), cache)


# ---------------------------------------------------------------------------
# Model


def model_init(key: jax.Array, cfg: ModelConfig, enc: packed.EncodingConfig) -> dict:
    ks = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab_size
    dt = cfg.activation_dtype
    n_groups, tail = _pattern_layout(cfg)

    # Vocab rows padded to a shardable multiple; ids never index the pad and
    # tied-head logits are sliced back to vocab_size.
    v_pad = v + ((-v) % max(256, enc.shard_multiple))
    params: dict[str, Any] = {
        "embed": (d**-0.5) * jax.random.normal(ks[0], (v_pad, d), jnp.float32).astype(dt),
        "final_norm": L.norm_init(cfg),
        "groups": _stacked_group_init(ks[1], cfg, enc, cfg.block_pattern, n_groups),
    }
    if tail:
        params["tail"] = _group_init(ks[2], cfg, enc, tail)
    if not cfg.tie_embeddings:
        params["head"] = packed.linear_init(ks[3], d, v, enc=enc, dtype=dt)

    if cfg.family == "encdec":
        params["enc_layers"] = _stacked_group_init(
            ks[4], cfg, enc, ("enc_attn",), cfg.encoder_layers
        )
        params["enc_final_norm"] = L.norm_init(cfg)
        params["dec_pos_embed"] = 0.02 * jax.random.normal(
            ks[5], (cfg.max_pos_embed, d), jnp.float32
        ).astype(dt)
    if cfg.family == "vlm":
        fd = cfg.frontend_dim or d
        params["projector"] = {
            "ln": L.norm_init(cfg, fd),
            "fc1": packed.linear_init(ks[6], fd, d, enc=enc, dtype=dt),
            "fc2": packed.linear_init(ks[7], d, d, enc=enc, dtype=dt),
        }
    return params


def cache_init(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    *,
    cache_mode: str = "dense",
    block_size: int = 16,
    num_pages: int | None = None,
    kv_quant: str = "bf16",
) -> dict:
    """Serving caches for every layer.

    cache_mode="dense": per-slot (batch, max_seq) KV rows (the PR-1 layout,
    kept as the parity baseline; the only mode for recurrent state).
    cache_mode="paged": per-layer page pool (num_pages, block_size) + block
    table — attention-only, no sliding window; the engine owns the page
    allocator (serving/paged.py) and threads tables through the cache leaves.
    kv_quant ("bf16"/"kv8"/"kv4"): the paged pool's KVLayout — quantized
    layouts add per-page float32 scale leaves next to the int pools
    (layers.attn_paged_cache_init); dense caches stay bf16 (the engine
    config downgrades kv_quant for dense mode).
    """
    assert cache_mode in ("dense", "paged"), cache_mode
    assert cache_mode == "paged" or kv_quant == "bf16", (
        "quantized KV layouts require the paged cache", cache_mode, kv_quant
    )
    n_groups, tail = _pattern_layout(cfg)
    if cache_mode == "paged":
        assert all(t == "attn" for t in cfg.block_pattern), (
            "paged KV cache requires an attention-only pattern; recurrent "
            "families keep dense state"
        )
        if num_pages is None:
            # Parity default: full dense coverage (+ scratch page 0).
            num_pages = 1 + batch * (-(-max_seq // block_size))

        def one(_t):
            return L.attn_paged_cache_init(
                cfg, batch, max_seq, block_size=block_size,
                num_pages=num_pages, kv_quant=kv_quant,
            )

        g = tuple(one(t) for t in cfg.block_pattern)
        caches = {"groups": _stack_caches(g, n_groups)}
        if tail:
            caches["tail"] = tuple(one(t) for t in tail)
        return caches
    g = _group_cache_init(cfg, cfg.block_pattern, batch, max_seq)
    caches = {"groups": _stack_caches(g, n_groups)}
    if tail:
        caches["tail"] = _group_cache_init(cfg, tail, batch, max_seq)
    return caches


def _run_encoder(params, frames, cfg, enc, phase):
    """Whisper encoder over precomputed frame embeddings (conv frontend stub)."""
    x = frames.astype(cfg.activation_dtype)
    # Sinusoidal positions.
    t = x.shape[1]
    pos = jnp.arange(t)[:, None]
    i = jnp.arange(cfg.d_model // 2)[None, :]
    ang = pos / jnp.power(10000.0, 2 * i / cfg.d_model)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(x.dtype)
    x = x + pe[None]

    apply = BLOCKS["enc_attn"][1]

    def body(xc, layer_params):
        y, _, _ = apply(layer_params, xc, cfg=cfg, enc=enc, phase=phase, cache=None, pos=0)
        return y, None

    x, _ = jax.lax.scan(lambda c, p: body(c, p[0]), x, params["enc_layers"])
    return L.norm_apply(params["enc_final_norm"], x, cfg)


def forward(
    params: dict,
    batch: dict,
    *,
    cfg: ModelConfig,
    enc: packed.EncodingConfig,
    phase: Phase,
    caches: dict | None = None,
    pos: jnp.ndarray | int = 0,
    last_logits_only: bool = False,
    logits_idx: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, dict | None, jnp.ndarray]:
    """Returns (logits, new_caches, aux_loss).

    batch: {"tokens": (B, S)} (+ "frames" (B,T,D) for audio, "patches"
    (B,P,Dv) for vision).  For decode, S == 1 and `pos` is the position of the
    incoming token — either a scalar shared by every row, or a (B,) vector of
    per-row positions (position-vectorized decode: one dispatch serves batch
    rows at different sequence depths; serving/engine.py).  S > 1 at DECODE is
    a masked-causal window (the spec-decode verify window, or the token-budget
    mixed step's per-row chunk of prompt tokens riding the same machinery).
    last_logits_only: emit logits for the final position only (serving
    prefill — avoids materializing the (B, S, V) tensor).  logits_idx: (B, K)
    int32 — emit logits only at these per-row window positions (B, K, V);
    the mixed step reads at most 1 + draft_k positions per row, so the head
    matmul must not scale with the chunk width S.  Overrides
    last_logits_only."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    d = cfg.d_model
    dt = cfg.activation_dtype
    x = params["embed"][tokens].astype(dt)

    extra = None
    if cfg.family == "encdec":
        if phase is not Phase.DECODE:
            extra = _run_encoder(params, batch["frames"], cfg, enc, phase)
        # pos > 0 for decode and chunked prefill; (B,) pos for vectorized decode.
        if jnp.asarray(pos).ndim == 1:
            posn = jnp.asarray(pos)[:, None] + jnp.arange(s)[None, :]
            x = x + params["dec_pos_embed"][posn]
        else:
            posn = pos + jnp.arange(s)
            x = x + params["dec_pos_embed"][posn][None]
    elif cfg.family == "vlm" and phase is not Phase.DECODE:
        pj = params["projector"]
        pimg = L.norm_apply(pj["ln"], batch["patches"].astype(dt), cfg)
        pimg = packed.linear_apply(pj["fc1"], pimg, n=d, phase=phase, enc=enc)
        pimg = jax.nn.gelu(pimg.astype(jnp.float32)).astype(dt)
        pimg = packed.linear_apply(pj["fc2"], pimg, n=d, phase=phase, enc=enc)
        x = jnp.concatenate([pimg, x], axis=1)  # image tokens prefix
        s = x.shape[1]

    n_groups, tail = _pattern_layout(cfg)
    pattern = cfg.block_pattern

    def make_body(pat):
        def group_body(carry, xs):
            xc, aux = carry
            gp, gc = xs
            new_gc = []
            for i, t in enumerate(pat):
                apply = BLOCKS[t][1]
                xc, c_new, a = apply(
                    gp[i], xc, cfg=cfg, enc=enc, phase=phase,
                    cache=None if gc is None else gc[i], pos=pos, extra=extra,
                )
                new_gc.append(c_new)
                aux = aux + a
            return (xc, aux), tuple(new_gc)

        if phase is Phase.TRAIN:
            return jax.checkpoint(group_body, prevent_cse=False)
        return group_body

    body = make_body(pattern)
    tail_body = make_body(tail) if tail else None

    aux0 = jnp.zeros((), jnp.float32)
    if caches is None:
        none_caches = tuple([None] * len(pattern))
        (x, aux), _ = jax.lax.scan(
            lambda c, gp: (body(c, (gp, none_caches))[0], None),
            (x, aux0),
            params["groups"],
        )
        new_caches = None
        if tail:
            (x, aux), _ = tail_body((x, aux), (params["tail"], tuple([None] * len(tail))))
    else:
        (x, aux), new_group_caches = jax.lax.scan(
            body, (x, aux0), (params["groups"], caches["groups"])
        )
        new_caches = {"groups": new_group_caches}
        if tail:
            xc, aux_c = x, aux
            new_tc = []
            for i, t in enumerate(tail):
                apply = BLOCKS[t][1]
                xc, c_new, a = apply(
                    params["tail"][i], xc, cfg=cfg, enc=enc, phase=phase,
                    cache=caches["tail"][i], pos=pos, extra=extra,
                )
                new_tc.append(c_new)
                aux_c = aux_c + a
            x, aux = xc, aux_c
            new_caches["tail"] = tuple(new_tc)

    if logits_idx is not None:
        # Per-row logit gather: row b keeps positions logits_idx[b] only.
        # (B, S, D) -> (B, K, D) before the head/tied-embed matmul.
        idx = jnp.asarray(logits_idx, jnp.int32)
        x = jnp.take_along_axis(x, idx[..., None], axis=1)
    elif last_logits_only:
        x = x[:, -1:, :]
    x = L.norm_apply(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", x, params["embed"], preferred_element_type=jnp.float32
        )[..., : cfg.vocab_size]
    else:
        logits = packed.linear_apply(
            params["head"], x, n=cfg.vocab_size, phase=phase, enc=enc,
            out_dtype=jnp.float32,
        )
    return logits, new_caches, aux


def loss_fn(params, batch, *, cfg, enc, rngs=None):
    """Next-token cross-entropy (train_step objective)."""
    logits, _, aux = forward(params, batch, cfg=cfg, enc=enc, phase=Phase.TRAIN)
    labels = batch["labels"]
    if cfg.family == "vlm":
        # Image-prefix positions carry no labels.
        pfx = logits.shape[1] - labels.shape[1]
        logits = logits[:, pfx:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll).mean()
    return nll + 0.01 * aux, {"nll": nll, "aux": aux}
