"""train_step: loss -> grads -> (optional compression) -> AdamW update.

Microbatch gradient accumulation runs as a lax.scan over batch slices so the
peak activation footprint is one microbatch; XLA overlaps the per-microbatch
reduce-scatters with the next microbatch's compute (latency-hiding scheduler).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.packed import EncodingConfig
from repro.models import transformer as T
from repro.parallel import compression
from repro.train import optimizer as opt_lib


def make_train_step(
    cfg,
    enc: EncodingConfig,
    opt_cfg: opt_lib.OptimizerConfig,
    *,
    microbatches: int = 1,
    compress_grads: bool = False,
):
    """Returns train_step(params, opt_state, batch, compress_state) -> ..."""

    def loss_fn(params, batch):
        return T.loss_fn(params, batch, cfg=cfg, enc=enc)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch, compress_state=None):
        if microbatches > 1:
            def slice_mb(i, x):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def mb_body(carry, i):
                acc, loss_acc = carry
                mb = jax.tree.map(functools.partial(slice_mb, i), batch)
                loss, _, grads = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(
                mb_body, (zero, jnp.zeros((), jnp.float32)), jnp.arange(microbatches)
            )
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = loss_sum / microbatches
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        new_compress_state = compress_state
        if compress_grads and compress_state is not None:
            grads, new_compress_state = compression.compress_decompress(
                grads, compress_state
            )

        new_params, new_opt, om = opt_lib.apply_updates(params, grads, opt_state, opt_cfg)
        out_metrics = {"loss": loss, **metrics, **om}
        return new_params, new_opt, out_metrics, new_compress_state

    return train_step


def make_eval_step(cfg, enc: EncodingConfig):
    def eval_step(params, batch):
        loss, metrics = T.loss_fn(params, batch, cfg=cfg, enc=enc)
        return {"loss": loss, **metrics}

    return eval_step
