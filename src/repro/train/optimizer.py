"""AdamW with ZeRO-sharded states, global-norm clipping, LR schedule.

Moment tensors are jnp.zeros_like(param) so they inherit each parameter's
(fully sharded) NamedSharding — ZeRO-1/2 falls out of the FSDP param specs.
Weight decay applies only to matmul weights (packed or plain); packed-layout
zero padding stays exactly zero under decoupled decay (grad is zero there and
decay multiplies zero).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # f32 moments by default; "bfloat16" halves optimizer HBM (production
    # profile for the 314B config — see EXPERIMENTS.md §Dry-run fit notes).
    moment_dtype: str = "float32"


def _is_matrix(path) -> bool:
    last = ""
    for p in path:
        if hasattr(p, "key"):
            last = str(p.key)
    return last in ("w_packed", "w_t", "embed") or last in (
        "w_gate", "w_up", "w_down",
    )


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.peak_lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params, cfg: OptimizerConfig | None = None) -> dict:
    mdt = jnp.dtype(cfg.moment_dtype) if cfg else jnp.float32
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, mdt), p)
    return {"mu": zeros(params), "nu": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: OptimizerConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])

    new_p, new_mu, new_nu = [], [], []
    for (path, p), g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        g = g.astype(jnp.float32) * scale
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        step_dir = (mu_n / bc1) / (jnp.sqrt(nu_n / bc2) + cfg.eps)
        wd = cfg.weight_decay if _is_matrix(path) else 0.0
        upd = p.astype(jnp.float32) - lr * (step_dir + wd * p.astype(jnp.float32))
        new_p.append(upd.astype(p.dtype))
        new_mu.append(mu_n.astype(mu.dtype))
        new_nu.append(nu_n.astype(nu.dtype))

    unflatten = jax.tree_util.tree_unflatten
    new_state = {
        "mu": unflatten(treedef, new_mu),
        "nu": unflatten(treedef, new_nu),
        "step": step + 1,
    }
    return unflatten(treedef, new_p), new_state, {"lr": lr, "grad_norm": gnorm}
