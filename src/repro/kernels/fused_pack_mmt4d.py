"""BEYOND-PAPER Pallas kernel: fused pack + mmt4d + unpack.

IREE materializes `tensor.pack(lhs)` and `tensor.unpack(out)` as separate ops,
paying two extra HBM round-trips per matmul (packed-lhs write+read, packed-out
write+read).  Weights are packed once so their round-trip amortizes to zero —
but activations don't.  On TPU the HBM->VMEM copy machinery can read *strided
slabs* of the 2-D activation directly, so the pack of the LHS and the unpack of
the output can live entirely inside the matmul kernel:

    lhs  : (M, K)   plain 2-D          (read in (BM, BK) slabs)
    rhs4 : (N1, K1, N0, K0)  packed    (weights: packed once at load)
    out  : (M, N)   plain 2-D          (written in (BM, BN) slabs)

Saved HBM traffic per matmul ≈ 2*M*K*s + 2*M*N*4 bytes — measured in
EXPERIMENTS.md §Perf.  The in-kernel relayout of the rhs tile
((BK1, N0, K0) -> (BK1*K0, N0)) happens in VMEM/registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pl_compat


def _fused_kernel(lhs_ref, rhs_ref, out_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    bn1, bk1, n0, k0 = rhs_ref.shape
    lhs = lhs_ref[...]  # (BM, BK1*K0)
    # Implicit "pack": the MXU contraction consumes the 2-D slab directly.
    # rhs tile relayout (VMEM-local): (BN1, BK1, N0, K0) -> (BK1*K0, BN1*N0).
    rhs = rhs_ref[...].transpose(1, 3, 0, 2).reshape(bk1 * k0, bn1 * n0)
    acc_ref[...] += jax.lax.dot_general(
        lhs,
        rhs,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("blocks", "out_dtype", "acc_dtype", "interpret"),
)
def fused_pack_mmt4d_pallas(
    lhs: jnp.ndarray,
    rhs4: jnp.ndarray,
    *,
    blocks: tuple[int, int, int] = (1, 1, 1),
    out_dtype=jnp.float32,
    acc_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """lhs (M, K) x packed rhs (N1, K1, N0, K0) -> out (M, N1*N0).

    blocks = (BM1, BN1, BK1) in units of (M0=rhs K0-matched rows, N0, K0) tiles;
    BM rows per step = BM1 * 128 (MXU-aligned slab).  M and K must be
    tile-aligned (ops.py pads).
    """
    m, k = lhs.shape
    n1, k1, n0, k0 = rhs4.shape
    assert k == k1 * k0, (lhs.shape, rhs4.shape)
    bm1, bn1, bk1 = blocks
    bm = bm1 * 128
    assert m % bm == 0 and n1 % bn1 == 0 and k1 % bk1 == 0, (
        (m, n1, k1),
        blocks,
    )
    grid = (m // bm, n1 // bn1, k1 // bk1)

    return pl.pallas_call(
        functools.partial(_fused_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk1 * k0), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn1, bk1, n0, k0), lambda i, j, kk: (j, kk, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn1 * n0), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n1 * n0), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn1 * n0), acc_dtype)],
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="fused_pack_mmt4d",
    )(lhs, rhs4)
