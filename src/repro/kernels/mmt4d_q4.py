"""Pallas TPU microkernels: w4a8 group-quantized mmt4d (prefill) and fused
GEMV (decode) — the paper's Llama.cpp-Q4-class weight format, data-tiled.

The 4-bit path exists for one reason: decode is weight-streaming-bound
(§Roofline), and int4 halves the dominant HBM term again over w8a8.  Weights
are stored in the mmt4d packed layout with two's-complement nibbles packed two
per byte along K0 (byte j of a tile row holds elements 2j, 2j+1) plus one f32
scale per `group` (default 32) consecutive K elements:

    rhs4_p (N1, K1, N0, K0/2) uint8      s_w4 (N1, K1, N0, K0/group) f32

Unlike w8a8, the per-K-group scale cannot factor out of the contraction into
the epilogue — each group's partial sum carries its own scale — so both
kernels fuse the dequant *into* the contraction: nibbles unpack and scale to
f32 VMEM-locally (per streamed weight tile, never materialized in HBM) and the
MXU contracts f32.  Products |a_q * w_q| <= 127*7 are exact in f32; the
activation's per-row scale s_a still factors into the epilogue.

    fused_gemv_q4_pallas : decode — plain int8 activation rows in, N-streaming
                           grid, plain f32 rows out (pack/unpack-free, the
                           fused_gemv.py contract)
    mmt4d_q4_pallas      : prefill — blocked (M1, N1, K1) grid over packed
                           operands, f32 accumulator scratch
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pl_compat


def _dequant_tile(rhs_block: jnp.ndarray, sw_block: jnp.ndarray, group: int):
    """(..., N0, K0/2) packed nibbles + (..., N0, K0/group) scales
    -> (..., N0, K0) f32, VMEM-local."""
    bi = rhs_block.astype(jnp.int32)
    lo = ((bi & 0xF) ^ 8) - 8
    hi = ((bi >> 4) ^ 8) - 8
    w = jnp.stack([lo, hi], axis=-1).reshape(
        *rhs_block.shape[:-1], 2 * rhs_block.shape[-1]
    ).astype(jnp.float32)
    s = jnp.broadcast_to(
        sw_block.astype(jnp.float32)[..., :, None], (*sw_block.shape, group)
    ).reshape(w.shape)
    return w * s


def _fused_gemv_q4_kernel(lhs_ref, rhs_ref, sa_ref, sw_ref, out_ref, *, group):
    bn1, k1, n0, k0p = rhs_ref.shape
    k0 = 2 * k0p
    lhs = lhs_ref[...].astype(jnp.float32)  # (M, K1*K0) int8 rows
    w = _dequant_tile(rhs_ref[...], sw_ref[...], group)  # (BN1, K1, N0, K0)
    rhs = w.transpose(1, 3, 0, 2).reshape(k1 * k0, bn1 * n0)
    acc = jax.lax.dot_general(
        lhs,
        rhs,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = (acc * sa_ref[...]).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bn1", "group", "out_dtype", "interpret")
)
def fused_gemv_q4_pallas(
    lhs_q: jnp.ndarray,   # (M, K) int8 activation rows
    rhs4_p: jnp.ndarray,  # (N1, K1, N0, K0/2) uint8 nibble-packed weights
    s_a: jnp.ndarray,     # (M, 1) f32 per-row activation scales
    s_w4: jnp.ndarray,    # (N1, K1, N0, K0/group) f32 per-group weight scales
    *,
    bn1: int = 1,
    group: int = 32,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """w4a8 fused decode GEMV: out (M, N1*N0) = (a_q @ deq(w4)^T) * s_a."""
    m, k = lhs_q.shape
    n1, k1, n0, k0p = rhs4_p.shape
    k0 = 2 * k0p
    assert k == k1 * k0, (lhs_q.shape, rhs4_p.shape)
    assert k0 % group == 0, (k0, group)
    assert s_a.shape == (m, 1), (s_a.shape, m)
    assert s_w4.shape == (n1, k1, n0, k0 // group), (s_w4.shape, rhs4_p.shape)
    assert n1 % bn1 == 0, (n1, bn1)
    grid = (n1 // bn1,)

    return pl.pallas_call(
        functools.partial(_fused_gemv_q4_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((bn1, k1, n0, k0p), lambda j: (j, 0, 0, 0)),
            pl.BlockSpec((m, 1), lambda j: (0, 0)),
            pl.BlockSpec((bn1, k1, n0, k0 // group), lambda j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bn1 * n0), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n1 * n0), out_dtype),
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="fused_gemv_q4",
    )(lhs_q, rhs4_p, s_a, s_w4)


def _mmt4d_q4_kernel(
    lhs_ref, rhs_ref, sa_ref, sw_ref, out_ref, acc_ref, *, k_steps, group
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    bm1, bk1 = lhs_ref.shape[0], lhs_ref.shape[1]
    bn1 = rhs_ref.shape[0]
    for a in range(bm1):
        for b in range(bn1):
            acc = acc_ref[a, b]
            for c in range(bk1):
                w = _dequant_tile(rhs_ref[b, c], sw_ref[b, c], group)
                acc = acc + jax.lax.dot_general(
                    lhs_ref[a, c].astype(jnp.float32),
                    w,
                    dimension_numbers=(((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
            acc_ref[a, b] = acc

    @pl.when(k == k_steps - 1)
    def _epilogue():
        acc = acc_ref[...]
        sa = sa_ref[...]  # (BM1, M0)
        out_ref[...] = (acc * sa[:, None, :, None]).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("blocks", "group", "out_dtype", "interpret")
)
def mmt4d_q4_pallas(
    lhs4_q: jnp.ndarray,  # (M1, K1, M0, K0) int8
    rhs4_p: jnp.ndarray,  # (N1, K1, N0, K0/2) uint8
    s_a: jnp.ndarray,     # (M1, M0) f32
    s_w4: jnp.ndarray,    # (N1, K1, N0, K0/group) f32
    *,
    blocks: tuple[int, int, int] = (1, 1, 1),
    group: int = 32,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    m1, k1, m0, k0 = lhs4_q.shape
    n1, k1r, n0, k0p = rhs4_p.shape
    assert (k1, k0) == (k1r, 2 * k0p), (lhs4_q.shape, rhs4_p.shape)
    assert k0 % group == 0, (k0, group)
    assert s_w4.shape == (n1, k1, n0, k0 // group), (s_w4.shape, rhs4_p.shape)
    bm1, bn1, bk1 = blocks
    assert m1 % bm1 == 0 and n1 % bn1 == 0 and k1 % bk1 == 0
    grid = (m1 // bm1, n1 // bn1, k1 // bk1)

    return pl.pallas_call(
        functools.partial(_mmt4d_q4_kernel, k_steps=grid[2], group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm1, bk1, m0, k0), lambda i, j, k: (i, k, 0, 0)),
            pl.BlockSpec((bn1, bk1, n0, k0p), lambda i, j, k: (j, k, 0, 0)),
            pl.BlockSpec((bm1, m0), lambda i, j, k: (i, 0)),
            pl.BlockSpec(
                (bn1, bk1, n0, k0 // group), lambda i, j, k: (j, k, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((bm1, bn1, m0, n0), lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m1, n1, m0, n0), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm1, bn1, m0, n0), jnp.float32)],
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="mmt4d_q4",
    )(lhs4_q, rhs4_p, s_a, s_w4)
