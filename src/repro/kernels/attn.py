"""Pallas TPU attention microkernels — the attention op class.

PRs 1-4 microkernel-ized every matmul in the serving path, but attention
stayed plain XLA: `attention_decode` softmaxes over the full cache and the
paged path materializes the whole logical KV view (`paged_gather`, a fresh
(B, NB*bs, KV, D) dense copy) on EVERY decode dispatch — at long contexts
that gather traffic dominates the weight stream the matmul kernels shrank
(V-Seek's point: optimized-GEMV decode is attention/KV-bound).  This module
gives all three attention phases a hand-tiled kernel:

  paged_decode_attention  decode against the page pool DIRECTLY: the block
                          table rides as a scalar-prefetch operand and the
                          kernel's BlockSpec index_map gathers K/V pages
                          tile-by-tile inside the dispatch — no materialized
                          logical view, and only the slot's LIVE pages are
                          streamed (beyond-live grid steps clamp their index
                          map to the last live page, so the pipelined copy is
                          elided, and their compute is `pl.when`-skipped).
  dense_decode_attention  the dense-cache analogue: K/V chunks streamed with
                          the same online softmax, ring-window mask included.
  flash_prefill_attention tiled causal GQA flash attention (the Pallas
                          analogue of layers.attention_chunked), q-offset
                          aware so chunked prefill rides the same kernel.

All three share one online-softmax accumulator (`_online_update`), keep the
running (m, l, acc) state in VMEM scratch across the innermost grid
dimension, and support per-row position vectors and the L > 1 masked-causal
spec-decode verify window.  A fully-masked chunk is an EXACT no-op of the
accumulator (m unchanged -> corr == 1.0, p == 0), which makes skip-by-mask
bitwise identical to skip-by-guard — the paged and dense kernels produce
bit-identical outputs whenever their streaming granularity matches
(dense kv_chunk == page block size; tests/test_attn_kernels.py pins this).

Quantized KV layouts (core/encoding.KVLayout, kv8/kv4): the paged and dense
decode kernels ride the per-page scale arrays as extra BlockSpec operands —
same index maps as their data pages, so a scale tile arrives in VMEM with
its page — and dequantize tile-locally before the online-softmax accumulate.
The contraction itself never sees int storage, and nothing dequantized is
ever written back to HBM.  Prefill writes quantized through the engine's
scatter path (models/layers.py quantizes per page on write); chunked-prefill
continuation reads its prior pages back through these same dequantizing
decode kernels.

Dispatch routing lives in kernels/registry.py (`select_attn`, the second op
class: attn|phase|S-bucket[|kv]|target); models/layers.py consults it per
call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import encoding
from repro.kernels import pl_compat


def _online_update(s, valid, v, m_ref, l_ref, acc_ref):
    """One online-softmax step over a scored chunk.

    s: (L, KV, G, C) f32 scores; valid: bool broadcastable to s;
    v: (C, KV, D) values; scratch m/l: (L, KV, G), acc: (L, KV, G, D).
    Fully-masked chunks leave (m, l, acc) bitwise unchanged (corr == 1).
    """
    s = jnp.where(valid, s, -jnp.inf)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # Guard rows with no valid key yet: keep the exponent argument finite.
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(valid, p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "lkgc,ckd->lkgd", p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new


def _init_state(m_ref, l_ref, acc_ref):
    m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _finalize(out_ref, l_ref, acc_ref, shape, dtype):
    out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
    out_ref[...] = out.reshape(shape).astype(dtype)


def _norm_pos(pos, b) -> jnp.ndarray:
    """Scalar or (B,) position of q[:, 0] -> (B,) int32."""
    p = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(jnp.atleast_1d(p), (b,))


def _dequant_kv(kv_quant: str, k_raw, v_raw, ks, vs):
    """VMEM-tile dequantization: int storage tiles + their scale tiles ->
    float32 (bs, KV, D) chunks the shared online-softmax body consumes.
    bf16 passes the raw tiles through untouched."""
    if kv_quant == "bf16":
        return k_raw, v_raw
    lay = encoding.kv_layout(kv_quant)
    return lay.dequantize(k_raw, ks), lay.dequantize(v_raw, vs)


# ---------------------------------------------------------------------------
# Fused paged-decode attention (in-kernel block-table gather)


def _paged_decode_kernel(
    table_ref, pos_ref, q_ref, k_ref, v_ref, *refs,
    bs: int, L: int, kvh: int, g: int, scale: float, kv_quant: str,
):
    if kv_quant == "bf16":
        ks_ref = vs_ref = None
        out_ref, m_ref, l_ref, acc_ref = refs
    else:
        ks_ref, vs_ref, out_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _():
        _init_state(m_ref, l_ref, acc_ref)

    pos_b = pos_ref[b]
    last = pos_b + L - 1  # last written position of this row's verify window

    # Beyond-live pages are never attended (their index map already clamps
    # to the last live page, so no fresh bytes moved either).
    @pl.when(j * bs <= last)
    def _():
        d = q_ref.shape[-1]
        qg = q_ref[0].reshape(L, kvh, g, d) * scale
        # (bs, KV, D) — ONE pool page (+ its scale page), gathered via
        # index map and dequantized here in VMEM for quantized layouts.
        k, v = _dequant_kv(
            kv_quant, k_ref[0], v_ref[0],
            None if ks_ref is None else ks_ref[0],
            None if vs_ref is None else vs_ref[0],
        )
        s = jnp.einsum(
            "lkgd,ckd->lkgc", qg, k, preferred_element_type=jnp.float32
        )
        slot = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, bs), 3)
        qpos = pos_b + jax.lax.broadcasted_iota(jnp.int32, (L, 1, 1, 1), 0)
        valid = slot <= qpos  # masked-causal inside the verify window
        _online_update(s, valid, v, m_ref, l_ref, acc_ref)

    @pl.when(j == nb - 1)
    def _():
        _finalize(out_ref, l_ref, acc_ref, (1, L, kvh * g, q_ref.shape[-1]),
                  out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kv_quant", "interpret"))
def paged_decode_attention(
    q: jnp.ndarray,       # (B, L, H, D)
    k_pool: jnp.ndarray,  # (P, bs, KV, Ds) physical pages (Ds = stored D)
    v_pool: jnp.ndarray,  # (P, bs, KV, Ds)
    table: jnp.ndarray,   # (B, NB) int32 page ids (logical block -> page)
    pos: jnp.ndarray,     # () or (B,) int32 position of q[:, 0]
    *,
    k_scale: jnp.ndarray | None = None,  # (P, bs, KV, 1) f32 scale pages
    v_scale: jnp.ndarray | None = None,
    kv_quant: str = "bf16",
    interpret: bool = False,
) -> jnp.ndarray:
    """Decode attention straight off the page pool: gathers each row's live
    K/V pages inside the kernel (scalar-prefetched block table drives the
    BlockSpec index map), online softmax over the page stream, per-row
    positions, full attention only (the paged cache excludes ring windows).
    L > 1 is the spec-decode verify window (masked-causal; the caller has
    already scattered all L K/V pairs into the pool).

    Streams ceil((pos+L)/bs) pages per row instead of materializing the
    (B, NB*bs, KV, D) `paged_gather` view — the O(pool) -> O(live) win.

    Quantized layouts (kv_quant "kv8"/"kv4"): the pools hold int storage
    (kv4 packs two nibbles per byte along D) and `k_scale`/`v_scale` are
    the matching scale pages; each grid step's scale tile rides the SAME
    index map as its data page and is dequantized in VMEM right before
    the score contraction — the scale stream adds 4 bytes/token/head
    against the >= 2x shrink of the data stream.
    """
    b, L, h, d = q.shape
    _, bs, kvh, ds = k_pool.shape
    nb = table.shape[1]
    g = h // kvh
    scale = d**-0.5
    posv = _norm_pos(pos, b)
    quantized = kv_quant != "bf16"
    assert (k_scale is not None) == quantized, (kv_quant, k_scale is None)

    def live_block(bi, j, tbl, pv):
        # Clamp beyond-live steps to the last live page: the block index is
        # then unchanged from the previous step and the copy is elided.
        return tbl[bi, jnp.minimum(j, (pv[bi] + L - 1) // bs)]

    def page_spec(width):
        return pl.BlockSpec(
            (1, bs, kvh, width),
            lambda bi, j, tbl, pv: (live_block(bi, j, tbl, pv), 0, 0, 0),
        )

    in_specs = [
        pl.BlockSpec((1, L, h, d), lambda bi, j, tbl, pv: (bi, 0, 0, 0)),
        page_spec(ds),
        page_spec(ds),
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        in_specs += [page_spec(1), page_spec(1)]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, L, h, d), lambda bi, j, tbl, pv: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((L, kvh, g), jnp.float32),
            pltpu.VMEM((L, kvh, g), jnp.float32),
            pltpu.VMEM((L, kvh, g, d), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_decode_kernel,
        bs=bs, L=L, kvh=kvh, g=g, scale=scale, kv_quant=kv_quant,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, L, h, d), q.dtype),
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="paged_decode_attention",
    )(table.astype(jnp.int32), posv, *operands)


# ---------------------------------------------------------------------------
# Dense-cache decode attention (ring-window aware)


def _dense_decode_kernel(
    pos_ref, q_ref, k_ref, v_ref, *refs,
    kc: int, s_c: int, window: int, L: int, kvh: int, g: int, scale: float,
    kv_quant: str,
):
    if kv_quant == "bf16":
        ks_ref = vs_ref = None
        out_ref, m_ref, l_ref, acc_ref = refs
    else:
        ks_ref, vs_ref, out_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def _():
        _init_state(m_ref, l_ref, acc_ref)

    pos_b = pos_ref[b]
    last = pos_b + L - 1
    # Full attention skips chunks past the newest written slot; a ring cache
    # may hold valid (wrapped) positions in every chunk, so it visits all.
    run = (j * kc <= last) if window == 0 else (j >= 0)

    @pl.when(run)
    def _():
        d = q_ref.shape[-1]
        qg = q_ref[0].reshape(L, kvh, g, d) * scale
        k, v = _dequant_kv(
            kv_quant, k_ref[0], v_ref[0],
            None if ks_ref is None else ks_ref[0],
            None if vs_ref is None else vs_ref[0],
        )
        s = jnp.einsum(
            "lkgd,ckd->lkgc", qg, k, preferred_element_type=jnp.float32
        )
        slot = j * kc + jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, kc), 3)
        qpos = pos_b + jax.lax.broadcasted_iota(jnp.int32, (L, 1, 1, 1), 0)
        # Tail guard: when kc does not divide S_c the last block reads past
        # the cache (Pallas pads the edge block; content is undefined) —
        # mask those columns out of the scores AND zero their V rows so no
        # garbage bit pattern (even a NaN encoding, pre- or post-dequant)
        # can reach the accumulator through 0 * v.
        in_range = slot < s_c
        v = jnp.where(
            (j * kc + jax.lax.broadcasted_iota(jnp.int32, (kc, 1, 1), 0)) < s_c,
            v, 0.0,
        )
        if window > 0:
            # Same mask as layers.attention_decode: rows still inside the
            # window take the cheap prefix mask (nothing wrapped or aged
            # out yet); only wrapped rows pay the ring-age mod.
            age = jnp.mod(qpos - slot, s_c)
            ring = age < jnp.minimum(qpos + 1, window)
            valid = jnp.where(qpos < window, slot <= qpos, ring) & in_range
        else:
            valid = (slot <= qpos) & in_range
        _online_update(s, valid, v, m_ref, l_ref, acc_ref)

    @pl.when(j == nk - 1)
    def _():
        _finalize(out_ref, l_ref, acc_ref, (1, L, kvh * g, q_ref.shape[-1]),
                  out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "kv_chunk", "kv_quant", "interpret")
)
def dense_decode_attention(
    q: jnp.ndarray,        # (B, L, H, D)
    k_cache: jnp.ndarray,  # (B, S_c, KV, Ds)
    v_cache: jnp.ndarray,  # (B, S_c, KV, Ds)
    pos: jnp.ndarray,      # () or (B,) int32 position of q[:, 0]
    *,
    window: int = 0,
    kv_chunk: int | None = None,
    k_scale: jnp.ndarray | None = None,  # (B, S_c, KV, 1) f32
    v_scale: jnp.ndarray | None = None,
    kv_quant: str = "bf16",
    interpret: bool = False,
) -> jnp.ndarray:
    """Dense-cache decode attention: K/V streamed in kv_chunk slabs with the
    same online softmax as the paged kernel (kv_chunk == page block size
    gives bit-identical outputs — kv8 included, since both kernels dequantize
    the identical tile values in the identical accumulate order), ring-window
    mask for sliding-window caches, per-row positions, L > 1 masked-causal
    verify window (window == 0 only — the same contract
    layers.attention_decode enforces).  Quantized layouts stream the scale
    slabs alongside their K/V chunks and dequantize in VMEM; ring windows
    stay bf16 (the paged pool owns the quantized serving path)."""
    b, L, h, d = q.shape
    _, s_c, kvh, ds = k_cache.shape
    assert L == 1 or window == 0, (L, window)
    quantized = kv_quant != "bf16"
    assert (k_scale is not None) == quantized, (kv_quant, k_scale is None)
    assert window == 0 or not quantized, (window, kv_quant)
    g = h // kvh
    scale = d**-0.5
    posv = _norm_pos(pos, b)
    kc = min(s_c, kv_chunk or 128)
    # No host-side padding: a ragged tail would force a full HBM copy of
    # both caches per dispatch; the kernel masks the edge block instead.
    nk = pl.cdiv(s_c, kc)

    def live_chunk(bi, j, pv):
        if window > 0:
            return j  # ring chunks are all potentially live
        return jnp.minimum(j, (pv[bi] + L - 1) // kc)

    def chunk_spec(width):
        return pl.BlockSpec(
            (1, kc, kvh, width),
            lambda bi, j, pv: (bi, live_chunk(bi, j, pv), 0, 0),
        )

    in_specs = [
        pl.BlockSpec((1, L, h, d), lambda bi, j, pv: (bi, 0, 0, 0)),
        chunk_spec(ds),
        chunk_spec(ds),
    ]
    operands = [q, k_cache, v_cache]
    if quantized:
        in_specs += [chunk_spec(1), chunk_spec(1)]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, L, h, d), lambda bi, j, pv: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((L, kvh, g), jnp.float32),
            pltpu.VMEM((L, kvh, g), jnp.float32),
            pltpu.VMEM((L, kvh, g, d), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _dense_decode_kernel,
        kc=kc, s_c=s_c, window=window, L=L, kvh=kvh, g=g, scale=scale,
        kv_quant=kv_quant,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, L, h, d), q.dtype),
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="dense_decode_attention",
    )(posv, *operands)


# ---------------------------------------------------------------------------
# Flash prefill (tiled causal GQA)


def _flash_prefill_kernel(
    q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
    *, qc: int, kc: int, sk: int, q_offset: int, causal: bool, window: int,
    kvh: int, g: int, scale: float,
):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _():
        _init_state(m_ref, l_ref, acc_ref)

    q_end = q_offset + (i + 1) * qc - 1  # last query position of this band
    run = (j * kc <= q_end) if (causal and window == 0) else (j >= 0)

    @pl.when(run)
    def _():
        d = q_ref.shape[-1]
        qg = q_ref[0].reshape(qc, kvh, g, d) * scale
        s = jnp.einsum(
            "qkgd,ckd->qkgc", qg, k_ref[0], preferred_element_type=jnp.float32
        )  # (qc, KV, G, kc) — query-chunk axis plays the L role below
        qpos = (
            q_offset + i * qc
            + jax.lax.broadcasted_iota(jnp.int32, (qc, 1, 1, 1), 0)
        )
        kpos = j * kc + jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, kc), 3)
        # Edge-block guard (kc may not divide Sk): mask the scores and zero
        # the V tail so undefined padded reads can never reach the
        # accumulator (see the dense kernel note).
        valid = kpos < sk
        v = jnp.where(
            (j * kc + jax.lax.broadcasted_iota(jnp.int32, (kc, 1, 1), 0)) < sk,
            v_ref[0], 0.0,
        )
        if causal:
            valid = valid & (kpos <= qpos)
        if window > 0:
            valid = valid & (kpos > qpos - window)
        valid = jnp.broadcast_to(valid, (qc, 1, 1, kc))
        _online_update(s, valid, v, m_ref, l_ref, acc_ref)

    @pl.when(j == nk - 1)
    def _():
        _finalize(out_ref, l_ref, acc_ref, (1, qc, kvh * g, q_ref.shape[-1]),
                  out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "q_chunk", "kv_chunk", "interpret"
    ),
)
def flash_prefill_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, KV, D)
    v: jnp.ndarray,  # (B, Sk, KV, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    q_chunk: int = 128,
    kv_chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled flash prefill: the Pallas analogue of layers.attention_chunked.
    Causal GQA with sliding-window and q-offset support (chunked prefill
    passes the absolute offset of q[:, 0]); upper-triangle KV chunks are
    skipped (index map clamps, compute is pl.when-guarded)."""
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = d**-0.5
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    # No host-side padding (full Q/K/V HBM copies); edge blocks are masked
    # in-kernel, and out-of-range output rows are masked writes.
    nq = pl.cdiv(sq, qc)
    nk = pl.cdiv(sk, kc)

    def k_block(bi, i, j):
        if causal and window == 0:
            # Clamp beyond-diagonal chunks to the band's last needed chunk.
            return jnp.minimum(j, (q_offset + (i + 1) * qc - 1) // kc)
        return j

    kernel = functools.partial(
        _flash_prefill_kernel,
        qc=qc, kc=kc, sk=sk, q_offset=q_offset, causal=causal, window=window,
        kvh=kvh, g=g, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qc, h, d), lambda bi, i, j: (bi, i, 0, 0)),
            pl.BlockSpec((1, kc, kvh, d), lambda bi, i, j: (bi, k_block(bi, i, j), 0, 0)),
            pl.BlockSpec((1, kc, kvh, d), lambda bi, i, j: (bi, k_block(bi, i, j), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, h, d), lambda bi, i, j: (bi, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qc, kvh, g), jnp.float32),
            pltpu.VMEM((qc, kvh, g), jnp.float32),
            pltpu.VMEM((qc, kvh, g, d), jnp.float32),
        ],
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_prefill_attention",
    )(q, k, v)
    return out
