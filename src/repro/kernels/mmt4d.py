"""Pallas TPU microkernel: linalg.mmt4d, prefill/train (GEMM) variant.

The paper's prefill microkernel holds an M0 x (N0 lanes) accumulator block in
vector registers and streams K.  The TPU adaptation holds a
(BM1*M0) x (BN1*N0) f32 accumulator block in VMEM scratch, feeds the MXU with
(M0, K0) x (N0, K0)^T native 128x128 tiles, and streams BK1 pack-tiles of K per
grid step.  Grid is (M-blocks, N-blocks, K-blocks) with K innermost so the
accumulator revisits are adjacent.

Operands are in mmt4d packed layout (see kernels/ref.py):
    lhs4: (M1, K1, M0, K0)
    rhs4: (N1, K1, N0, K0)   # transposed operand
    out4: (M1, N1, M0, N0)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pl_compat


def _mmt4d_kernel(lhs_ref, rhs_ref, out_ref, acc_ref, *, k_steps: int):
    """One grid step: acc[bm1, bn1] += sum_bk lhs[bm1, bk] @ rhs[bn1, bk]^T."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    bm1, bk1 = lhs_ref.shape[0], lhs_ref.shape[1]
    bn1 = rhs_ref.shape[0]
    # Statically unrolled tile loop: every dot is a native (M0,K0)x(N0,K0)^T
    # MXU contraction — no in-kernel 4-D transposes (Mosaic-friendly).
    for a in range(bm1):
        for b in range(bn1):
            acc = acc_ref[a, b]
            for c in range(bk1):
                acc = acc + jax.lax.dot_general(
                    lhs_ref[a, c],
                    rhs_ref[b, c],
                    dimension_numbers=(((1,), (1,)), ((), ())),
                    preferred_element_type=acc_ref.dtype,
                )
            acc_ref[a, b] = acc

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("blocks", "out_dtype", "acc_dtype", "interpret"),
)
def mmt4d_pallas(
    lhs4: jnp.ndarray,
    rhs4: jnp.ndarray,
    *,
    blocks: tuple[int, int, int] = (1, 1, 1),
    out_dtype=jnp.float32,
    acc_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """Packed-layout GEMM. blocks = (BM1, BN1, BK1) pack-tiles per grid step.

    Block factors must divide (M1, N1, K1); `ops.mmt4d` computes legal ones
    from `encoding.select_kernel_blocks`.
    """
    m1, k1, m0, k0 = lhs4.shape
    n1, k1r, n0, k0r = rhs4.shape
    assert (k1, k0) == (k1r, k0r), (lhs4.shape, rhs4.shape)
    bm1, bn1, bk1 = blocks
    assert m1 % bm1 == 0 and n1 % bn1 == 0 and k1 % bk1 == 0, (
        (m1, n1, k1),
        blocks,
    )
    grid = (m1 // bm1, n1 // bn1, k1 // bk1)
    k_steps = grid[2]

    return pl.pallas_call(
        functools.partial(_mmt4d_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm1, bk1, m0, k0), lambda i, j, k: (i, k, 0, 0)),
            pl.BlockSpec((bn1, bk1, n0, k0), lambda i, j, k: (j, k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm1, bn1, m0, n0), lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m1, n1, m0, n0), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm1, bn1, m0, n0), acc_dtype)],
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="mmt4d_gemm",
    )(lhs4, rhs4)
