"""Pallas TPU microkernel: int8 mmt4d (weights-and-activations quantized).

Beyond-paper serving extension: the paper ships f16xf16->f32 microkernels and
motivates custom kernels via mixed precision; TPU v5e's MXU runs int8 at 2x
bf16 throughput and int8 weights halve the decode weight-streaming bound (the
§Roofline decode bottleneck).  Factorized symmetric quantization keeps the
matmul exact w.r.t. the quantized operands:

    out[m, n] = s_a[m] * s_w[n] * sum_k a_q[m,k] * w_q[n,k]      (s32 accum)

  * weights: per-output-channel scale (s_w), packed once (serving format)
  * activations: per-row dynamic scale (s_a), quantized on the fly
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pl_compat


def _mmt4d_q8_kernel(lhs_ref, rhs_ref, sa_ref, sw_ref, out_ref, acc_ref, *, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    bm1, bk1 = lhs_ref.shape[0], lhs_ref.shape[1]
    bn1 = rhs_ref.shape[0]
    for a in range(bm1):
        for b in range(bn1):
            acc = acc_ref[a, b]
            for c in range(bk1):
                acc = acc + jax.lax.dot_general(
                    lhs_ref[a, c],
                    rhs_ref[b, c],
                    dimension_numbers=(((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.int32,
                )
            acc_ref[a, b] = acc

    @pl.when(k == k_steps - 1)
    def _epilogue():
        # (BM1, BN1, M0, N0) * s_a (BM1, M0) * s_w (BN1, N0)
        acc = acc_ref[...].astype(jnp.float32)
        sa = sa_ref[...]  # (BM1, M0)
        sw = sw_ref[...]  # (BN1, N0)
        out_ref[...] = (
            acc * sa[:, None, :, None] * sw[None, :, None, :]
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("blocks", "out_dtype", "interpret")
)
def mmt4d_q8_pallas(
    lhs4_q: jnp.ndarray,   # (M1, K1, M0, K0) int8
    rhs4_q: jnp.ndarray,   # (N1, K1, N0, K0) int8
    s_a: jnp.ndarray,      # (M1, M0) f32 per-row scales
    s_w: jnp.ndarray,      # (N1, N0) f32 per-channel scales
    *,
    blocks: tuple[int, int, int] = (1, 1, 1),
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    m1, k1, m0, k0 = lhs4_q.shape
    n1, k1r, n0, k0r = rhs4_q.shape
    assert (k1, k0) == (k1r, k0r)
    bm1, bn1, bk1 = blocks
    assert m1 % bm1 == 0 and n1 % bn1 == 0 and k1 % bk1 == 0
    grid = (m1 // bm1, n1 // bn1, k1 // bk1)

    return pl.pallas_call(
        functools.partial(_mmt4d_q8_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm1, bk1, m0, k0), lambda i, j, k: (i, k, 0, 0)),
            pl.BlockSpec((bn1, bk1, n0, k0), lambda i, j, k: (j, k, 0, 0)),
            pl.BlockSpec((bm1, m0), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn1, n0), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm1, bn1, m0, n0), lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m1, n1, m0, n0), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm1, bn1, m0, n0), jnp.int32)],
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="mmt4d_q8",
    )(lhs4_q, rhs4_q, s_a, s_w)
