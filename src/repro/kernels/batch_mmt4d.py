"""Pallas TPU microkernel: linalg.batch_mmt4d.

IREE's encoding pipeline also lowers *batched* contractions (attention
score/context matmuls at short sequence lengths) to `linalg.batch_mmt4d`
microkernels; the paper implemented only the unbatched mmt4d for RISC-V.
This is the TPU batch variant for layout-parity with IREE's op set:

    lhs: (B, M1, K1, M0, K0)   rhs: (B, N1, K1, N0, K0)
    out: (B, M1, N1, M0, N0)   f32 accumulation

The model's long-context attention path intentionally does NOT use it — the
flash-chunked attention (models/layers.py) has strictly better memory
behaviour at 32k+; batch_mmt4d covers the short-S regime and completes the
microkernel library.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pl_compat


def _batch_mmt4d_kernel(lhs_ref, rhs_ref, out_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    bm1, bk1 = lhs_ref.shape[1], lhs_ref.shape[2]
    bn1 = rhs_ref.shape[1]
    for a in range(bm1):
        for b in range(bn1):
            acc = acc_ref[0, a, b]
            for c in range(bk1):
                acc = acc + jax.lax.dot_general(
                    lhs_ref[0, a, c],
                    rhs_ref[0, b, c],
                    dimension_numbers=(((1,), (1,)), ((), ())),
                    preferred_element_type=acc_ref.dtype,
                )
            acc_ref[0, a, b] = acc

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("blocks", "out_dtype", "acc_dtype", "interpret")
)
def batch_mmt4d_pallas(
    lhs5: jnp.ndarray,
    rhs5: jnp.ndarray,
    *,
    blocks: tuple[int, int, int] = (1, 1, 1),
    out_dtype=jnp.float32,
    acc_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    bsz, m1, k1, m0, k0 = lhs5.shape
    bsz2, n1, k1r, n0, k0r = rhs5.shape
    assert bsz == bsz2 and (k1, k0) == (k1r, k0r), (lhs5.shape, rhs5.shape)
    bm1, bn1, bk1 = blocks
    assert m1 % bm1 == 0 and n1 % bn1 == 0 and k1 % bk1 == 0
    grid = (bsz, m1 // bm1, n1 // bn1, k1 // bk1)

    return pl.pallas_call(
        functools.partial(_batch_mmt4d_kernel, k_steps=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm1, bk1, m0, k0), lambda b, i, j, k: (b, i, k, 0, 0)),
            pl.BlockSpec((1, bn1, bk1, n0, k0), lambda b, i, j, k: (b, j, k, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, bm1, bn1, m0, n0), lambda b, i, j, k: (b, i, j, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, m1, n1, m0, n0), out_dtype),
        scratch_shapes=[pltpu.VMEM((1, bm1, bn1, m0, n0), acc_dtype)],
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="batch_mmt4d",
    )(lhs5, rhs5)


def batch_mmt4d_ref(lhs5: jnp.ndarray, rhs5: jnp.ndarray, acc_dtype=jnp.float32):
    return jnp.einsum(
        "zmkac,znkbc->zmnab", lhs5, rhs5, preferred_element_type=acc_dtype
    )
