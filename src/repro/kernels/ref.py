"""Pure-jnp oracles for every kernel in this package.

These are (a) the correctness references the Pallas kernels are validated
against in tests, and (b) the `xla` backend used by the 512-device dry-run —
XLA lowers the einsum on the packed 4-D layout directly, which keeps
cost_analysis faithful to the mmt4d compute while avoiding interpret-mode
blow-up at dry-run scale.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def pack(x: jnp.ndarray, tile: tuple[int, int]) -> jnp.ndarray:
    """tensor.pack: (R, C) -> (R1, C1, T0, T1), zero-padded, tiles contiguous."""
    t0, t1 = tile
    r, c = x.shape
    r1 = math.ceil(r / t0)
    c1 = math.ceil(c / t1)
    xp = jnp.pad(x, ((0, r1 * t0 - r), (0, c1 * t1 - c)))
    return xp.reshape(r1, t0, c1, t1).transpose(0, 2, 1, 3)


def unpack(y: jnp.ndarray, shape: tuple[int, int]) -> jnp.ndarray:
    """tensor.unpack: (R1, C1, T0, T1) -> (R, C), cropping pad."""
    r1, c1, t0, t1 = y.shape
    r, c = shape
    return y.transpose(0, 2, 1, 3).reshape(r1 * t0, c1 * t1)[:r, :c]


def mmt4d(lhs4: jnp.ndarray, rhs4: jnp.ndarray, acc_dtype=jnp.float32) -> jnp.ndarray:
    """linalg.mmt4d: lhs (M1,K1,M0,K0) x rhs (N1,K1,N0,K0) -> (M1,N1,M0,N0).

    out[m1,n1,m0,n0] = sum_{k1,k0} lhs[m1,k1,m0,k0] * rhs[n1,k1,n0,k0]
    (rhs is the transposed operand — the trailing 't').  f32 accumulation,
    matching the paper's f16xf16->f32 microkernels.
    """
    return jnp.einsum(
        "mkac,nkbc->mnab",
        lhs4,
        rhs4,
        preferred_element_type=acc_dtype,
    )


def mmt4d_unfused(
    lhs: jnp.ndarray,
    rhs_t: jnp.ndarray,
    tiles: tuple[int, int, int],
    acc_dtype=jnp.float32,
) -> jnp.ndarray:
    """Full encoded matmul on 2-D operands: pack -> mmt4d -> unpack.

    lhs: (M, K); rhs_t: (N, K) (already transposed, as stored by PackedLinear).
    Returns (M, N) in acc_dtype.
    """
    m0, n0, k0 = tiles
    m, k = lhs.shape
    n, k2 = rhs_t.shape
    assert k == k2, (lhs.shape, rhs_t.shape)
    lhs4 = pack(lhs, (m0, k0))
    rhs4 = pack(rhs_t, (n0, k0))
    out4 = mmt4d(lhs4, rhs4, acc_dtype=acc_dtype)
    return unpack(out4, (m, n))


def matmul_reference(lhs: jnp.ndarray, rhs_t: jnp.ndarray, acc_dtype=jnp.float32) -> jnp.ndarray:
    """The un-encoded baseline (upstream-IREE analogue): plain contraction."""
    return jnp.einsum("mk,nk->mn", lhs, rhs_t, preferred_element_type=acc_dtype)


# ---- int8 serving quantization (beyond paper; kernels/mmt4d_q8.py) ---------


def quantize_rows(x2d: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-row int8: returns (q (R, C) int8, scale (R,) f32)."""
    s = jnp.maximum(jnp.max(jnp.abs(x2d.astype(jnp.float32)), axis=1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x2d.astype(jnp.float32) / s[:, None]), -127, 127)
    return q.astype(jnp.int8), s


_CLIP_RATIOS = (1.0, 0.95, 0.9, 0.85, 0.8, 0.75, 0.7)


def quantize_rows_mse(
    x2d: jnp.ndarray, ratios: tuple[float, ...] = _CLIP_RATIOS
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-row int8 with MSE-optimal clipping.

    Absmax scales waste resolution on per-row outliers; searching a few clip
    ratios and keeping the min-MSE quantization per row roughly halves weight
    reconstruction error.  One-time cost — used for WEIGHT packing
    (ops.pack_rhs_q8); dynamic activation quant keeps plain absmax."""
    xf = x2d.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf), axis=1), 1e-8)
    best_err = best_q = best_s = None
    for r in ratios:
        s = amax * (r / 127.0)
        q = jnp.clip(jnp.round(xf / s[:, None]), -127, 127)
        err = jnp.sum(jnp.square(q * s[:, None] - xf), axis=1)
        if best_err is None:
            best_err, best_q, best_s = err, q, s
        else:
            upd = err < best_err
            best_q = jnp.where(upd[:, None], q, best_q)
            best_s = jnp.where(upd, s, best_s)
            best_err = jnp.minimum(err, best_err)
    return best_q.astype(jnp.int8), best_s


def mmt4d_q8(lhs4_q, rhs4_q, s_a, s_w) -> jnp.ndarray:
    """Oracle for kernels/mmt4d_q8.py (same operand layout)."""
    acc = jnp.einsum(
        "mkac,nkbc->mnab",
        lhs4_q.astype(jnp.int32),
        rhs4_q.astype(jnp.int32),
    ).astype(jnp.float32)
    return acc * s_a[:, None, :, None] * s_w[None, :, None, :]


# ---- int4 group-quantized serving (w4a8; kernels/mmt4d_q4.py) --------------

# K elements sharing one int4 scale.  16 is the serving default: on the
# reduced-model decision-preservation harness it halves the logit MSE of the
# llama.cpp-Q4_0-style g=32 (rel MSE 0.035 vs 0.078) for +1/16 scale byte per
# weight (bf16 scales) — see docs/PERF.md for the measured trade-off curve.
Q4_GROUP = 16


def quantize_rows_q4_grouped(
    x2d: jnp.ndarray,
    group: int = Q4_GROUP,
    ratios: tuple[float, ...] = _CLIP_RATIOS,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-(row, K-group) int4 with MSE-optimal clipping.

    Returns (q (R, C) int8 in [-7, 7], scales (R, ceil(C/group)) f32).  A
    per-group scale is the whole point of 4-bit: one outlier only costs its
    own `group` neighbours resolution, not the full row.  C is zero-padded to
    a group multiple internally; padded columns quantize to 0 and never
    contribute (their dequant is 0 * scale)."""
    r, c = x2d.shape
    gcount = math.ceil(c / group)
    cp = gcount * group
    xf = jnp.pad(x2d.astype(jnp.float32), ((0, 0), (0, cp - c)))
    xg = xf.reshape(r, gcount, group)
    amax = jnp.maximum(jnp.max(jnp.abs(xg), axis=2), 1e-8)  # (R, G)
    best_err = best_q = best_s = None
    for ratio in ratios:
        s = amax * (ratio / 7.0)
        q = jnp.clip(jnp.round(xg / s[..., None]), -7, 7)
        err = jnp.sum(jnp.square(q * s[..., None] - xg), axis=2)
        if best_err is None:
            best_err, best_q, best_s = err, q, s
        else:
            upd = err < best_err
            best_q = jnp.where(upd[..., None], q, best_q)
            best_s = jnp.where(upd, s, best_s)
            best_err = jnp.minimum(err, best_err)
    q2d = best_q.reshape(r, cp)[:, :c].astype(jnp.int8)
    return q2d, best_s


def pack_nibbles(q: jnp.ndarray) -> jnp.ndarray:
    """int4-valued int8 (..., C) -> uint8 (..., C/2), two's-complement nibbles.

    Byte j holds elements (2j, 2j+1): low nibble = even index.  C must be
    even (the packed K0 tile is 128, always even)."""
    assert q.shape[-1] % 2 == 0, q.shape
    qi = q.astype(jnp.int32) & 0xF
    lo = qi[..., 0::2]
    hi = qi[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(b: jnp.ndarray) -> jnp.ndarray:
    """uint8 (..., P) -> int32 in [-8, 7] (..., 2P), inverse of pack_nibbles."""
    bi = b.astype(jnp.int32)
    lo = ((bi & 0xF) ^ 8) - 8
    hi = ((bi >> 4) ^ 8) - 8
    return jnp.stack([lo, hi], axis=-1).reshape(*b.shape[:-1], 2 * b.shape[-1])


def dequant_rhs4_q4(
    rhs4_p: jnp.ndarray, s_w4: jnp.ndarray, group: int = Q4_GROUP
) -> jnp.ndarray:
    """Nibble-packed rhs (N1, K1, N0, K0/2) + scales (N1, K1, N0, K0/group)
    -> f32 (N1, K1, N0, K0): the dequantized packed weight."""
    w = unpack_nibbles(rhs4_p).astype(jnp.float32)
    n1, k1, n0, k0 = w.shape
    s = jnp.broadcast_to(
        s_w4.astype(jnp.float32)[..., :, None], (*s_w4.shape, group)
    ).reshape(n1, k1, n0, k0)
    return w * s


def mmt4d_q4(lhs4_q, rhs4_p, s_a, s_w4, group: int = Q4_GROUP) -> jnp.ndarray:
    """Oracle for kernels/mmt4d_q4.py: w4a8 mmt4d on packed operands.

    lhs4_q (M1, K1, M0, K0) int8 activations + per-row scales s_a (M1, M0);
    rhs4_p nibble-packed int4 weights + per-group scales s_w4 (see
    dequant_rhs4_q4).  The per-K-group weight scale cannot factor out of the
    contraction (unlike w8a8's per-channel scale), so the weight dequantizes
    into f32 *inside* the contraction domain and accumulation is f32 — the
    products are exact in f32 (|a_q| <= 127, |w_q| <= 7)."""
    w = dequant_rhs4_q4(rhs4_p, s_w4, group)
    acc = jnp.einsum(
        "mkac,nkbc->mnab",
        lhs4_q.astype(jnp.float32),
        w,
        preferred_element_type=jnp.float32,
    )
    return acc * s_a[:, None, :, None]
