"""Pallas TPU microkernels: tensor.pack / tensor.unpack.

IREE lowers tensor.pack/unpack to generic microkernels; on TPU these are pure
relayout (memory-bound) kernels.  Each grid step copies a slab of whole tiles
through VMEM, doing the 2-D -> 4-D (or inverse) relayout on-chip, so HBM sees
only contiguous reads and contiguous writes.

Both kernels require tile-aligned 2-D operands; `ops.pack` pads with XLA first
(pad is fused into the producer by XLA, so the kernel never sees ragged edges).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pl_compat


def _pack_kernel(x_ref, out_ref):
    br1, bc1, t0, t1 = out_ref.shape
    x = x_ref[...]  # (br1*t0, bc1*t1)
    out_ref[...] = x.reshape(br1, t0, bc1, t1).transpose(0, 2, 1, 3)


def _unpack_kernel(x_ref, out_ref):
    br1, bc1, t0, t1 = x_ref.shape
    x = x_ref[...]
    out_ref[...] = x.transpose(0, 2, 1, 3).reshape(br1 * t0, bc1 * t1)


@functools.partial(jax.jit, static_argnames=("tile", "blocks", "interpret"))
def pack_pallas(
    x: jnp.ndarray,
    *,
    tile: tuple[int, int],
    blocks: tuple[int, int] = (1, 1),
    interpret: bool = False,
) -> jnp.ndarray:
    """(R, C) -> (R1, C1, T0, T1). R, C must be multiples of the tile."""
    t0, t1 = tile
    r, c = x.shape
    assert r % t0 == 0 and c % t1 == 0, (x.shape, tile)
    r1, c1 = r // t0, c // t1
    br1, bc1 = blocks
    assert r1 % br1 == 0 and c1 % bc1 == 0, ((r1, c1), blocks)
    grid = (r1 // br1, c1 // bc1)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br1 * t0, bc1 * t1), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br1, bc1, t0, t1), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((r1, c1, t0, t1), x.dtype),
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="tensor_pack",
    )(x)


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def unpack_pallas(
    x4: jnp.ndarray,
    *,
    blocks: tuple[int, int] = (1, 1),
    interpret: bool = False,
) -> jnp.ndarray:
    """(R1, C1, T0, T1) -> (R1*T0, C1*T1). Crop (if any) is done by the caller."""
    r1, c1, t0, t1 = x4.shape
    br1, bc1 = blocks
    assert r1 % br1 == 0 and c1 % bc1 == 0, (x4.shape, blocks)
    grid = (r1 // br1, c1 // bc1)
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br1, bc1, t0, t1), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((br1 * t0, bc1 * t1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r1 * t0, c1 * t1), x4.dtype),
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="tensor_unpack",
    )(x4)
