"""Pallas TPU microkernel: linalg.mmt4d, decode (GEMV-class) variant.

The paper ships a *separate* decode microkernel (M0=1, N0=VLEN/4): decode is a
weight-streaming, bandwidth-bound GEMV.  TPU analogue: the packed activation
row-block (all of K for the <=sublane-group of live batch rows) stays resident
in VMEM for the whole kernel; the grid walks N only, so every packed weight
byte moves HBM->VMEM exactly once and there is no K-revisit of the accumulator
(single-shot dot per grid step — no scratch, no grid-minor accumulation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pl_compat


def _mmt4d_gemv_kernel(lhs_ref, rhs_ref, out_ref):
    """One grid step: out[0, b] = sum_k1 lhs[0, k1] @ rhs[b, k1]^T (full K)."""
    k1 = lhs_ref.shape[1]
    bn1 = rhs_ref.shape[0]
    for b in range(bn1):
        acc = jnp.zeros(out_ref.shape[2:], out_ref.dtype)
        for c in range(k1):
            acc = acc + jax.lax.dot_general(
                lhs_ref[0, c],
                rhs_ref[b, c],
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=out_ref.dtype,
            )
        out_ref[0, b] = acc


@functools.partial(
    jax.jit,
    static_argnames=("bn1", "out_dtype", "interpret"),
)
def mmt4d_gemv_pallas(
    lhs4: jnp.ndarray,
    rhs4: jnp.ndarray,
    *,
    bn1: int = 1,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """Packed-layout GEMV. lhs4 must have M1 == 1 (decode row block).

    bn1 = packed N tiles per grid step; must divide N1.
    """
    m1, k1, m0, k0 = lhs4.shape
    n1, k1r, n0, k0r = rhs4.shape
    assert m1 == 1, f"decode kernel expects a single packed row block, got M1={m1}"
    assert (k1, k0) == (k1r, k0r), (lhs4.shape, rhs4.shape)
    assert n1 % bn1 == 0, (n1, bn1)
    grid = (n1 // bn1,)

    return pl.pallas_call(
        _mmt4d_gemv_kernel,
        grid=grid,
        in_specs=[
            # Full K row block, resident across the whole grid.
            pl.BlockSpec((1, k1, m0, k0), lambda j: (0, 0, 0, 0)),
            # Weight stream: each block visited exactly once.
            pl.BlockSpec((bn1, k1, n0, k0), lambda j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn1, m0, n0), lambda j: (0, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n1, m0, n0), out_dtype),
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="mmt4d_gemv",
    )(lhs4, rhs4)
