"""Pallas TPU microkernel: fused pack + mmt4d-GEMV + unpack (decode fast path).

The decode analogue of `fused_pack_mmt4d.py`.  The unfused decode projection
(`encoded_matmul` backend="pallas", Phase.DECODE) pays two activation HBM
round-trips per projection that the weight-streaming GEMV itself never needed:

    ref.pack(x)    : write (M1,K1,M0,K0) + read it back          (2*M*K*s bytes)
    ref.unpack(out): write (M1,N1,M0,N0) f32 + read it back      (2*M*N*4 bytes)

At decode those transfers are the same order as the activation row itself, and
the paper's whole decode story (V-Seek; §Roofline here) is that this regime is
bandwidth-bound — so the pack and unpack move *into* the kernel:

    lhs  : (M, K)   plain 2-D activation rows (M = live decode slots, tiny)
    rhs4 : (N1, K1, N0, K0)  packed weights, streamed HBM->VMEM exactly once
    out  : (M, N)   plain 2-D, written in (M, BN1*N0) slabs

The grid walks N only (weight streaming); the full activation row block stays
resident in VMEM for the whole kernel, exactly like `mmt4d_gemv.py`, and the
rhs tile relayout ((BN1, K1, N0, K0) -> (K1*K0, BN1*N0)) happens VMEM-locally.

`fused_gemv_q8_pallas` is the w8a8 variant: int8 activation rows + int8 packed
weights, s32 accumulation, with the factorized-scale epilogue
(out = acc * s_a[m] * s_w[n]) fused into the same single dispatch — the int8
path previously paid the identical pack/unpack round-trips plus a separate
scale multiply over the (M1,N1,M0,N0) tensor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pl_compat


def _fused_gemv_kernel(lhs_ref, rhs_ref, out_ref):
    """One grid step: out[:, j-block] = lhs @ relayout(rhs-block)^T (full K)."""
    bn1, k1, n0, k0 = rhs_ref.shape
    lhs = lhs_ref[...]  # (M, K1*K0) — implicit "pack": consumed directly.
    # Weight tile relayout (VMEM-local): (BN1, K1, N0, K0) -> (K1*K0, BN1*N0).
    rhs = rhs_ref[...].transpose(1, 3, 0, 2).reshape(k1 * k0, bn1 * n0)
    # Single-shot dot per grid step: no K-revisit, no accumulator scratch.
    out_ref[...] = jax.lax.dot_general(
        lhs,
        rhs,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype,
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn1", "out_dtype", "interpret"))
def fused_gemv_pallas(
    lhs: jnp.ndarray,
    rhs4: jnp.ndarray,
    *,
    bn1: int = 1,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """lhs (M, K) x packed rhs (N1, K1, N0, K0) -> out (M, N1*N0).

    M is the live decode row count (padded by ops.py to a sublane multiple);
    K must equal K1*K0 (ops.py mirrors the packed K padding).  bn1 = packed N
    tiles streamed per grid step; must divide N1.
    """
    m, k = lhs.shape
    n1, k1, n0, k0 = rhs4.shape
    assert k == k1 * k0, (lhs.shape, rhs4.shape)
    assert n1 % bn1 == 0, (n1, bn1)
    grid = (n1 // bn1,)

    return pl.pallas_call(
        _fused_gemv_kernel,
        grid=grid,
        in_specs=[
            # Full activation row block, resident across the whole grid.
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            # Weight stream: each packed block visited exactly once.
            pl.BlockSpec((bn1, k1, n0, k0), lambda j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bn1 * n0), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n1 * n0), out_dtype),
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="fused_gemv",
    )(lhs, rhs4)


def _fused_gemv_q8_kernel(lhs_ref, rhs_ref, sa_ref, sw_ref, out_ref):
    bn1, k1, n0, k0 = rhs_ref.shape
    lhs = lhs_ref[...]  # (M, K1*K0) int8
    rhs = rhs_ref[...].transpose(1, 3, 0, 2).reshape(k1 * k0, bn1 * n0)
    acc = jax.lax.dot_general(
        lhs,
        rhs,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    # Fused factorized-scale epilogue: out = acc * s_a[m] * s_w[n].
    sa = sa_ref[...]                      # (M, 1) f32
    sw = sw_ref[...].reshape(1, bn1 * n0)  # (BN1, N0) -> row vector
    out_ref[...] = (acc.astype(jnp.float32) * sa * sw).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn1", "out_dtype", "interpret"))
def fused_gemv_q8_pallas(
    lhs_q: jnp.ndarray,   # (M, K) int8 activation rows
    rhs4_q: jnp.ndarray,  # (N1, K1, N0, K0) int8 packed weights
    s_a: jnp.ndarray,     # (M, 1) f32 per-row activation scales
    s_w: jnp.ndarray,     # (N1, N0) f32 per-channel weight scales
    *,
    bn1: int = 1,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """w8a8 fused decode GEMV: out (M, N1*N0) = (lhs_q @ rhs_q^T) * s_a * s_w."""
    m, k = lhs_q.shape
    n1, k1, n0, k0 = rhs4_q.shape
    assert k == k1 * k0, (lhs_q.shape, rhs4_q.shape)
    assert s_a.shape == (m, 1), (s_a.shape, m)
    assert s_w.shape == (n1, n0), (s_w.shape, rhs4_q.shape)
    assert n1 % bn1 == 0, (n1, bn1)
    grid = (n1 // bn1,)

    return pl.pallas_call(
        _fused_gemv_q8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((bn1, k1, n0, k0), lambda j: (j, 0, 0, 0)),
            pl.BlockSpec((m, 1), lambda j: (0, 0)),
            pl.BlockSpec((bn1, n0), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((m, bn1 * n0), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n1 * n0), out_dtype),
        compiler_params=pl_compat.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="fused_gemv_q8",
    )(lhs_q, rhs4_q, s_a, s_w)
