"""Pallas API compatibility: `pltpu.CompilerParams` was `TPUCompilerParams`
in older jax releases (<= 0.4.x).  Every kernel module takes the class from
here so the whole package tracks whichever name the installed jax provides.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
assert CompilerParams is not None, "no Pallas TPU CompilerParams class found"
