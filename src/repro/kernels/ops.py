"""Dispatch wrappers — the analogue of IREE's microkernel ABI boundary.

`encoded_matmul` is the single entry point the model zoo calls for every dense
projection.  It performs the paper's rewrite (pack -> mmt4d -> unpack) with
phase/target-selected tiles and routes the mmt4d to one of:

    backend="reference" : plain contraction, NO encoding (upstream-IREE analogue)
    backend="xla"       : pack + einsum-mmt4d + unpack, pure jnp (dry-run path)
    backend="pallas"    : the Pallas microkernels (prefill GEMM / decode GEMV)
    backend="fused"     : beyond-paper fused pack+mmt4d+unpack Pallas kernel

Layout-unification decision (TPU adaptation, see DESIGN.md §2): weights are
packed ONCE, in the GEMM-native (N0=128, K0=128) tile layout, and shared by
prefill and decode.  The paper's phase-specific tile rule (decode N0=VLEN/4)
is honoured at the *kernel block* level instead: the decode GEMV kernel streams
`bn1` adjacent N tiles per grid step (bn1*128 ≈ the paper's wide-N), so serving
does not hold two packed copies of every weight.

The same unification extends to the fused decode fast path: because weights
stay in the one GEMM-native packed layout, `backend="fused"` can serve BOTH
regimes from the same rhs4 buffer — prefill routes to the fused GEMM
(`fused_pack_mmt4d.py`, 128-row slabs) and decode routes to the fused GEMV
(`fused_gemv.py`, sublane-padded row block, N-only weight-streaming grid).
Neither path materializes a packed activation or packed output in HBM: the
pack of the LHS and the unpack of the result live inside the kernel, which at
decode removes ~2*M*K*s + 2*M*N*4 bytes of HBM traffic per projection — the
dominant non-weight traffic of the paper's bandwidth-bound decode regime (see
docs/PERF.md for the full accounting).  The w8a8 path gets the same treatment:
`fused_gemv_q8_pallas` folds the factorized-scale epilogue into the dispatch.
"""

from __future__ import annotations

import functools
from typing import Any

import jax.numpy as jnp

from repro.core import encoding
from repro.core import targets as targets_lib
from repro.kernels import fused_gemv as fused_gemv_lib
from repro.kernels import fused_pack_mmt4d as fused_lib
from repro.kernels import mmt4d as mmt4d_lib
from repro.kernels import mmt4d_gemv as gemv_lib
from repro.kernels import mmt4d_q4 as q4_lib
from repro.kernels import mmt4d_q8 as q8_lib
from repro.kernels import pack as pack_lib
from repro.kernels import ref
from repro.kernels import registry

Phase = encoding.Phase

# "auto" defers backend choice to the dispatch registry (kernels/registry.py):
# tuned table first, static policy second, reference fallback on unknown keys.
BACKENDS = ("reference", "xla", "pallas", "fused", "auto")

# Row ceiling for the fused decode GEMV: the full (M, K) activation block stays
# VMEM-resident across the whole grid, so M is bounded by the live decode slots
# (a few to a few dozen); larger fused matmuls take the 128-row GEMM slab path.
_FUSED_GEMV_MAX_ROWS = 256


def _largest_divisor_leq(n: int, cap: int) -> int:
    cap = max(1, min(n, cap))
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def pack_rhs(
    w_t: jnp.ndarray,
    *,
    tiles: encoding.TileSizes | None = None,
    target: targets_lib.TargetSpec = targets_lib.TPU_V5E,
    shard_multiple: int = 1,
) -> jnp.ndarray:
    """Pack a transposed weight (N, K) into (N1, K1, N0, K0). One-time cost.

    Always uses the GEMM-native layout (see layout-unification note above).
    `shard_multiple` pads the N1/K1 tile counts so they divide the mesh axes
    (production setting: 16); padding provably stays zero under training.
    """
    if tiles is None:
        tiles = encoding.select_tile_sizes(
            encoding.Phase.PREFILL, lhs_dtype=w_t.dtype, target=target
        )
    p4 = ref.pack(w_t, (tiles.n0, tiles.k0))
    if shard_multiple > 1:
        n1, k1, n0, k0 = p4.shape
        pn = (-n1) % shard_multiple
        pk = (-k1) % shard_multiple
        if pn or pk:
            p4 = jnp.pad(p4, ((0, pn), (0, pk), (0, 0), (0, 0)))
    return p4


def _select_m0(
    phase: Phase, dtype, m: int, target: targets_lib.TargetSpec
) -> int:
    if target.mxu_dim == 1:
        return encoding.paper_tile_sizes(phase).m0
    if phase is Phase.DECODE:
        sub = targets_lib.sublanes_for_dtype(target, jnp.dtype(dtype).itemsize)
        return max(1, min(sub, m))
    return target.mxu_dim


def _pad_rows(x2d: jnp.ndarray, mult: int) -> jnp.ndarray:
    pad = (-x2d.shape[0]) % mult
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    return x2d


def encoded_matmul(
    x: jnp.ndarray,
    rhs4: jnp.ndarray,
    *,
    n: int,
    phase: Phase,
    backend: str = "xla",
    m0: int | None = None,
    blocks: tuple[int, int, int] | None = None,
    target: targets_lib.TargetSpec = targets_lib.TPU_V5E,
    out_dtype: Any = None,
    acc_dtype: Any = jnp.float32,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """x (..., K) @ W^T where rhs4 is the packed (N1, K1, N0, K0) weight.

    Returns (..., n) in `out_dtype` (default: x.dtype). `acc_dtype` is the
    cross-shard reduction dtype (see EncodingConfig.reduce_dtype); in-shard
    MXU accumulation is f32 regardless.  `blocks` overrides the VMEM-model
    block selection (perf hillclimb knob).  `interpret=None` auto-detects:
    interpreted Pallas only when no TPU backend is present.
    """
    assert backend in BACKENDS, backend
    interpret = targets_lib.resolve_interpret(interpret)
    out_dtype = out_dtype or x.dtype
    n1, k1, n0, k0 = rhs4.shape
    k = x.shape[-1]
    assert k <= k1 * k0, (x.shape, rhs4.shape)
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k)
    m = x2d.shape[0]
    choice = registry.select(
        quant="none", phase=phase, m=m, target=target,
        requested=backend, blocks=blocks,
    )
    backend, blocks = choice.backend, choice.blocks
    if k != k1 * k0:  # K padding lives in the packed weight; mirror it on lhs.
        x2d = jnp.pad(x2d, ((0, 0), (0, k1 * k0 - k)))

    if backend == "reference":
        w_t = ref.unpack(rhs4, (n, k1 * k0))[:, :k]
        out = ref.matmul_reference(x2d[:, :k], w_t).astype(out_dtype)
        return out.reshape(*lead, n)

    if backend == "fused":
        if phase is Phase.DECODE and m <= _FUSED_GEMV_MAX_ROWS:
            # Decode fast path: fused GEMV — plain 2-D row block in, packed
            # weights streamed once, plain 2-D out. Rows pad to one sublane
            # group (8/16/32 by dtype), not the GEMM's 128-row slab.
            sub = targets_lib.sublanes_for_dtype(
                target, jnp.dtype(x.dtype).itemsize
            )
            xp = _pad_rows(x2d, sub)
            want_bn1 = (
                _gemv_bn1(n0, k0, k1, target, jnp.dtype(rhs4.dtype).itemsize)
                if blocks is None
                else blocks[1]
            )
            bn1 = _fused_gemv_plan(
                rows=xp.shape[0],
                n1=n1, k1=k1, n0=n0, k0=k0,
                lhs_itemsize=jnp.dtype(x.dtype).itemsize,
                rhs_itemsize=jnp.dtype(rhs4.dtype).itemsize,
                want_bn1=want_bn1,
                target=target,
            )
            if bn1 is not None:
                out2d = fused_gemv_lib.fused_gemv_pallas(
                    xp, rhs4, bn1=bn1, out_dtype=jnp.float32,
                    interpret=interpret,
                )
                return out2d[:m, :n].astype(out_dtype).reshape(*lead, n)
            # VMEM can't hold the resident row block even at bn1=1:
            # fall through to the 128-row GEMM slab path below.
        xp = _pad_rows(x2d, 128)
        want = blocks if blocks is not None else (4, 2, 4)
        # Clamp to divisors of this shape's tile counts: tuned/explicit blocks
        # are measured on representative shapes and must stay legal everywhere.
        bm1 = _largest_divisor_leq(xp.shape[0] // 128, want[0])
        bn1 = _largest_divisor_leq(n1, want[1])
        bk1 = _largest_divisor_leq(k1, want[2])
        out2d = fused_lib.fused_pack_mmt4d_pallas(
            xp,
            rhs4,
            blocks=(bm1, bn1, bk1),
            out_dtype=jnp.float32,
            interpret=interpret,
        )
        return out2d[:m, :n].astype(out_dtype).reshape(*lead, n)

    if m0 is None:
        m0 = _select_m0(phase, x.dtype, m, target)
    xp = _pad_rows(x2d, m0)
    m1 = xp.shape[0] // m0
    lhs4 = ref.pack(xp, (m0, k0))

    if backend == "xla":
        out4 = ref.mmt4d(lhs4, rhs4, acc_dtype=acc_dtype)
    elif phase is Phase.DECODE and m1 == 1:
        # The paper's decode GEMV microkernel: weight-streaming, wide-N blocks.
        want_bn1 = (
            _gemv_bn1(n0, k0, k1, target, jnp.dtype(rhs4.dtype).itemsize)
            if blocks is None
            else blocks[1]
        )
        bn1 = _largest_divisor_leq(n1, want_bn1)
        out4 = gemv_lib.mmt4d_gemv_pallas(lhs4, rhs4, bn1=bn1, interpret=interpret)
    else:
        # The paper's prefill GEMM microkernel (also used for skinny decode GEMM
        # when many batch rows are live).
        if blocks is None:
            tiles = encoding.TileSizes(m0=m0, n0=n0, k0=k0)
            kb = encoding.select_kernel_blocks(
                tiles,
                phase,
                m1=m1,
                n1=n1,
                k1=k1,
                lhs_itemsize=jnp.dtype(x.dtype).itemsize,
                rhs_itemsize=jnp.dtype(rhs4.dtype).itemsize,
                target=target,
            )
            blocks = (kb.bm1, kb.bn1, kb.bk1)
        bm1 = _largest_divisor_leq(m1, blocks[0])
        bn1 = _largest_divisor_leq(n1, blocks[1])
        bk1 = _largest_divisor_leq(k1, blocks[2])
        out4 = mmt4d_lib.mmt4d_pallas(
            lhs4, rhs4, blocks=(bm1, bn1, bk1), interpret=interpret
        )

    out2d = ref.unpack(out4, (xp.shape[0], n1 * n0))
    return out2d[:m, :n].astype(out_dtype).reshape(*lead, n)


def _fused_gemv_plan(
    *,
    rows: int,
    n1: int,
    k1: int,
    n0: int,
    k0: int,
    lhs_itemsize: int,
    rhs_itemsize: int,
    want_bn1: int,
    target: targets_lib.TargetSpec,
    per_tile_bytes: int | None = None,
) -> int | None:
    """VMEM-feasible bn1 for the fused GEMV, or None when none fits.

    Unlike the packed GEMV (whose lhs is one sublane-group row block), the
    fused kernel keeps the full (rows, K) activation block and an
    (rows, bn1*N0) f32 output slab resident alongside each streamed weight
    tile — all three must fit the kernel's half-VMEM budget (the other half
    is double-buffering headroom for the weight stream).  `per_tile_bytes`
    overrides the dense-rhs tile footprint for formats whose streamed bytes
    are not k1*n0*k0*itemsize (the nibble-packed w4a8 tile + its scales).
    """
    budget = target.vmem_bytes // 2
    lhs_bytes = rows * k1 * k0 * lhs_itemsize
    per_tile = (
        per_tile_bytes if per_tile_bytes is not None else k1 * n0 * k0 * rhs_itemsize
    )

    def fits(bn1: int) -> bool:
        return lhs_bytes + bn1 * per_tile + rows * bn1 * n0 * 4 <= budget

    bn1 = _largest_divisor_leq(n1, max(1, want_bn1))
    while bn1 > 1 and not fits(bn1):
        bn1 = _largest_divisor_leq(n1, bn1 - 1)
    return bn1 if fits(bn1) else None


def _gemv_bn1(
    n0: int,
    k0: int,
    k1: int,
    target: targets_lib.TargetSpec,
    rhs_itemsize: int = 2,
) -> int:
    """Decode streaming width: the paper's wide-N rule, VMEM-budgeted.

    select_tile_sizes(DECODE).n0 (=512 lanes on TPU) sets the *minimum* stream
    width; the ceiling is half of VMEM for the per-step weight block.
    """
    want = encoding.select_tile_sizes(Phase.DECODE, target=target).n0 // n0
    per_tile = k1 * n0 * k0 * rhs_itemsize
    cap = max(1, (target.vmem_bytes // 2) // max(per_tile, 1))
    return max(1, min(max(want, 1), cap))


# ---- int8 serving path (beyond paper) --------------------------------------


def pack_rhs_q8(
    w_t: jnp.ndarray, *, shard_multiple: int = 1
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize (per output channel) + pack. Returns (rhs4_q int8, s_w (N1,N0)).

    Weight rows use the MSE-optimal clip search (one-time cost at load);
    dynamic activation quantization stays absmax (encoded_matmul_q8)."""
    q, s = ref.quantize_rows_mse(w_t)
    rhs4 = pack_rhs(q, shard_multiple=shard_multiple)
    n1, _, n0, _ = rhs4.shape
    s_pad = jnp.zeros((n1 * n0,), jnp.float32).at[: s.shape[0]].set(s)
    return rhs4, s_pad.reshape(n1, n0)


def encoded_matmul_q8(
    x: jnp.ndarray,
    rhs4_q: jnp.ndarray,
    s_w: jnp.ndarray,
    *,
    n: int,
    phase: Phase,
    backend: str = "xla",
    blocks: tuple[int, int, int] | None = None,
    target: targets_lib.TargetSpec = targets_lib.TPU_V5E,
    out_dtype: Any = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """w8a8 encoded matmul: dynamic per-row activation quant, packed int8
    weights, s32 accumulation, factorized scales (see kernels/mmt4d_q8.py).

    backend="fused" at decode skips the activation pack and the output unpack
    entirely: quantized rows feed `fused_gemv_q8_pallas`, whose epilogue also
    folds in the s_a*s_w scale product (one dispatch, no HBM round-trips)."""
    interpret = targets_lib.resolve_interpret(interpret)
    out_dtype = out_dtype or x.dtype
    n1, k1, n0, k0 = rhs4_q.shape
    k = x.shape[-1]
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k)
    m = x2d.shape[0]
    choice = registry.select(
        quant="w8a8", phase=phase, m=m, target=target,
        requested=backend, blocks=blocks,
    )
    backend, blocks = choice.backend, choice.blocks
    if k != k1 * k0:
        x2d = jnp.pad(x2d, ((0, 0), (0, k1 * k0 - k)))
    xq, s_a = ref.quantize_rows(x2d)

    if backend == "fused" and phase is Phase.DECODE and m <= _FUSED_GEMV_MAX_ROWS:
        sub = targets_lib.sublanes_for_dtype(target, 1)
        xqp = _pad_rows(xq, sub)
        rows = xqp.shape[0]
        bn1 = _fused_gemv_plan(
            rows=rows, n1=n1, k1=k1, n0=n0, k0=k0,
            lhs_itemsize=1, rhs_itemsize=1,
            want_bn1=(
                _gemv_bn1(n0, k0, k1, target, 1)
                if blocks is None
                else blocks[1]
            ),
            target=target,
        )
        if bn1 is not None:
            sa2 = jnp.zeros((rows, 1), jnp.float32).at[:m, 0].set(s_a)
            out2d = fused_gemv_lib.fused_gemv_q8_pallas(
                xqp, rhs4_q, sa2, s_w, bn1=bn1, interpret=interpret
            )
            return out2d[:m, :n].astype(out_dtype).reshape(*lead, n)
        # No VMEM-feasible fused plan: fall through to the packed q8 path.

    m0 = _select_m0(phase, jnp.int8, m, target)
    xq = _pad_rows(xq, m0)
    m1 = xq.shape[0] // m0
    lhs4 = ref.pack(xq, (m0, k0))
    sa_pad = jnp.zeros((m1 * m0,), jnp.float32).at[:m].set(s_a)
    sa2 = sa_pad.reshape(m1, m0)

    if backend in ("pallas", "fused"):
        # "fused" outside the GEMV regime (prefill, big M, VMEM-infeasible)
        # still runs the packed Pallas q8 kernel, not the reference einsum.
        want = blocks if blocks is not None else (4, 4, 4)
        bm1 = _largest_divisor_leq(m1, want[0])
        bn1 = _largest_divisor_leq(n1, want[1])
        bk1 = _largest_divisor_leq(k1, want[2])
        out4 = q8_lib.mmt4d_q8_pallas(
            lhs4, rhs4_q, sa2, s_w, blocks=(bm1, bn1, bk1), interpret=interpret
        )
    else:
        out4 = ref.mmt4d_q8(lhs4, rhs4_q, sa2, s_w)
    out2d = ref.unpack(out4, (xq.shape[0], n1 * n0))
    return out2d[:m, :n].astype(out_dtype).reshape(*lead, n)


# ---- int4 group-quantized serving path (w4a8) ------------------------------


def pack_rhs_q4(
    w_t: jnp.ndarray,
    *,
    group: int = ref.Q4_GROUP,
    shard_multiple: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Group-quantize + pack a transposed weight (N, K) for the w4a8 path.

    Returns (rhs4_p (N1, K1, N0, K0/2) uint8 nibble-packed,
             s_w4 (N1, K1, N0, K0/group) f32 per-group scales).

    Quantization is per-(row, K-group) int4 with MSE-clip search (one-time
    load cost); the scales tensor mirrors the weight's tile structure so the
    kernels stream matching blocks.  Padded rows/columns carry zero scales
    and zero nibbles — their dequant is exactly 0."""
    assert 128 % group == 0, group  # groups must tile K0
    q, s = ref.quantize_rows_q4_grouped(w_t, group=group)
    tiles = encoding.select_tile_sizes(encoding.Phase.PREFILL)
    n0, k0 = tiles.n0, tiles.k0
    rhs4 = ref.pack(q, (n0, k0))          # (N1, K1, N0, K0) int8
    # Scales ship bf16: the scale stream is pure HBM overhead at decode and a
    # bf16 scale's rounding (<0.4% of the scale) is noise next to int4 error.
    s_w4 = ref.pack(s, (n0, k0 // group)).astype(jnp.bfloat16)
    if shard_multiple > 1:
        n1, k1, _, _ = rhs4.shape
        pn = (-n1) % shard_multiple
        pk = (-k1) % shard_multiple
        if pn or pk:
            rhs4 = jnp.pad(rhs4, ((0, pn), (0, pk), (0, 0), (0, 0)))
            s_w4 = jnp.pad(s_w4, ((0, pn), (0, pk), (0, 0), (0, 0)))
    return ref.pack_nibbles(rhs4), s_w4


def encoded_matmul_q4(
    x: jnp.ndarray,
    rhs4_p: jnp.ndarray,
    s_w4: jnp.ndarray,
    *,
    n: int,
    phase: Phase,
    group: int = ref.Q4_GROUP,
    backend: str = "xla",
    blocks: tuple[int, int, int] | None = None,
    target: targets_lib.TargetSpec = targets_lib.TPU_V5E,
    out_dtype: Any = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """w4a8 encoded matmul: dynamic per-row int8 activation quant, nibble-
    packed int4 weights with per-group scales (kernels/mmt4d_q4.py).

    The per-K-group scale rides inside the contraction (dequant fused into
    the kernel, per streamed tile); only the activation's per-row scale
    factors into the epilogue.  backend="fused" at decode is the
    pack/unpack-free GEMV; "pallas" (or fused outside the GEMV regime) is
    the blocked packed kernel; "xla" is the ref.mmt4d_q4 oracle."""
    interpret = targets_lib.resolve_interpret(interpret)
    out_dtype = out_dtype or x.dtype
    n1, k1, n0, k0p = rhs4_p.shape
    k0 = 2 * k0p
    k = x.shape[-1]
    lead = x.shape[:-1]
    x2d = x.reshape(-1, k)
    m = x2d.shape[0]
    choice = registry.select(
        quant="w4a8", phase=phase, m=m, target=target,
        requested=backend, blocks=blocks,
    )
    backend, blocks = choice.backend, choice.blocks
    if k != k1 * k0:
        x2d = jnp.pad(x2d, ((0, 0), (0, k1 * k0 - k)))
    xq, s_a = ref.quantize_rows(x2d)

    # Streamed w4 tile: nibble bytes + group-scale bytes (not a dense tile).
    scale_itemsize = jnp.dtype(s_w4.dtype).itemsize
    q4_tile_bytes = k1 * n0 * (k0p + (k0 // group) * scale_itemsize)

    if backend == "fused" and phase is Phase.DECODE and m <= _FUSED_GEMV_MAX_ROWS:
        sub = targets_lib.sublanes_for_dtype(target, 1)
        xqp = _pad_rows(xq, sub)
        rows = xqp.shape[0]
        bn1 = _fused_gemv_plan(
            rows=rows, n1=n1, k1=k1, n0=n0, k0=k0,
            lhs_itemsize=1, rhs_itemsize=1,
            want_bn1=(
                _gemv_bn1(n0, k0, k1, target, 1)
                if blocks is None
                else blocks[1]
            ),
            target=target,
            per_tile_bytes=q4_tile_bytes,
        )
        if bn1 is not None:
            sa2 = jnp.zeros((rows, 1), jnp.float32).at[:m, 0].set(s_a)
            out2d = q4_lib.fused_gemv_q4_pallas(
                xqp, rhs4_p, sa2, s_w4, bn1=bn1, group=group,
                interpret=interpret,
            )
            return out2d[:m, :n].astype(out_dtype).reshape(*lead, n)
        # No VMEM-feasible fused plan: fall through to the packed q4 path.

    m0 = _select_m0(phase, jnp.int8, m, target)
    xq = _pad_rows(xq, m0)
    m1 = xq.shape[0] // m0
    lhs4 = ref.pack(xq, (m0, k0))
    sa_pad = jnp.zeros((m1 * m0,), jnp.float32).at[:m].set(s_a)
    sa2 = sa_pad.reshape(m1, m0)

    if backend in ("pallas", "fused"):
        want = blocks if blocks is not None else (4, 4, 4)
        bm1 = _largest_divisor_leq(m1, want[0])
        bn1 = _largest_divisor_leq(n1, want[1])
        bk1 = _largest_divisor_leq(k1, want[2])
        out4 = q4_lib.mmt4d_q4_pallas(
            lhs4, rhs4_p, sa2, s_w4, blocks=(bm1, bn1, bk1), group=group,
            interpret=interpret,
        )
    else:
        out4 = ref.mmt4d_q4(lhs4, rhs4_p, sa2, s_w4, group=group)
    out2d = ref.unpack(out4, (xq.shape[0], n1 * n0))
    return out2d[:m, :n].astype(out_dtype).reshape(*lead, n)


# Re-exports for benchmarks/tests.  (The attention op class lives in
# kernels/attn.py and is routed by registry.select_attn from
# models/layers.attention_apply — its callers import that module directly.)
pack_pallas = pack_lib.pack_pallas
unpack_pallas = pack_lib.unpack_pallas
mmt4d_pallas = mmt4d_lib.mmt4d_pallas
mmt4d_gemv_pallas = gemv_lib.mmt4d_gemv_pallas
fused_pack_mmt4d_pallas = fused_lib.fused_pack_mmt4d_pallas
fused_gemv_pallas = fused_gemv_lib.fused_gemv_pallas
fused_gemv_q8_pallas = fused_gemv_lib.fused_gemv_q8_pallas
fused_gemv_q4_pallas = q4_lib.fused_gemv_q4_pallas
mmt4d_q4_pallas = q4_lib.mmt4d_q4_pallas


@functools.lru_cache(maxsize=None)
def default_tiles(phase: Phase, dtype_name: str = "bfloat16") -> encoding.TileSizes:
    return encoding.select_tile_sizes(phase, lhs_dtype=jnp.dtype(dtype_name))
