"""Unified microkernel dispatch registry — the analogue of IREE's ukernel
selection boundary (TinyIREE's "clean selection/deployment seam").

Every encoded matmul used to pick its implementation through scattered
`backend="fused"/"pallas"/"q8"` branching in ops.py call sites.  This module
centralizes the decision behind one key.  Two op classes share the table:

matmul (select()):

    (quant mode, phase, M-bucket, target name)  ->  KernelChoice(backend, blocks)

* quant mode : "none" (bf16/f32), "w8a8" (int8), "w4a8" (group int4)
* M-bucket   : live-row regime — "m1" (pure GEMV), "m8" (decode slots),
               "m32" (spec-decode verify: slots x draft window), "m64"
               (skinny GEMM), "big" (prefill slab); buckets keep the table
               finite while still separating the paper's two regimes.
* target     : TargetSpec.name from core/targets.py

attention (select_attn()):

    ("attn", phase, S-bucket[, kv-quant], target name)
        ->  KernelChoice(backend, blocks)

* S-bucket   : context-length regime — "s256"/"s1k"/"s4k"/"sbig" over the
               logical KV length the dispatch attends (cache width at
               decode, key length at prefill).  Attention cost scales with
               S the way matmul cost scales with M, so S plays the bucket
               role here.
* kv-quant   : the KV-cache storage layout the kernel streams ("bf16",
               "kv8", "kv4" — core/encoding.KV_QUANTS).  bf16 keys keep
               the legacy 4-segment form `attn|{phase}|{S}|{target}` so
               every checked-in tuned entry, fault-schedule fnmatch
               pattern, and quarantine record stays valid; quantized
               layouts insert the axis: `attn|{phase}|{S}|{kv}|{target}`.
               A kv-quant key with no tuned entry inherits the bf16
               entry's blocks (chunking geometry is dtype-independent
               until a retune says otherwise).
* backend    : "xla" (the jnp references layers.attention_decode /
               attention_chunked) or "pallas" (kernels/attn.py — paged or
               dense decode kernel, flash prefill).
* blocks     : (q_chunk, kv_chunk) streaming granularity for the Pallas
               kernels (decode uses kv_chunk only; the paged kernel streams
               at page granularity and ignores blocks).

Resolution order (both classes):
  1. an explicit `requested` backend always wins (tests/benches pin paths);
  2. a tuned-table entry for the key (blocks measured by
     `benchmarks/kernel_bench.py --tune`, persisted to the checked-in
     tuned_table.json next to this file);
  3. the static default policy (the routing ops.py used to hard-code);
  4. unknown key (unrecognized quant/phase/target): the reference path —
     dispatch must never crash on a target it has no data for.

Quarantine tier (docs/ROBUSTNESS.md): a dispatch that raises, or whose
output fails the serving engine's finite check, demotes its key down that
same ladder for the rest of the process — `demote(key, failing_backend)`
walks the rung list until the resolved backend CHANGES (a rung that would
re-pick the failing kernel is no mitigation), and `select`/`select_attn`
honour the recorded demotion level before anything else, including an
explicit `requested` backend.  Quarantine stores only a rung offset per
key, never code; `quarantine_snapshot()` is what Engine.stats surfaces as
`stats["degraded"]`.

The tuned table stores only data (backend name + kernel blocks), never code:
deployment-time dispatch is a dict lookup, and re-tuning is a JSON diff.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

from repro.core import encoding
from repro.core import targets as targets_lib

Phase = encoding.Phase

QUANTS = ("none", "w8a8", "w4a8")
M_BUCKETS = ("m1", "m8", "m32", "m64", "big")

# Backends each quant mode understands (ops.py contract).  "auto" is the
# registry sentinel, resolved here and never passed to a kernel.
BACKENDS_BY_QUANT = {
    "none": ("reference", "xla", "pallas", "fused"),
    "w8a8": ("xla", "pallas", "fused"),
    "w4a8": ("xla", "pallas", "fused"),
}

# The no-data escape hatch per quant mode.  For quantized modes "xla" IS the
# reference oracle (ref.mmt4d_q8 / ref.mmt4d_q4 on the packed operands).
FALLBACK_BACKEND = {"none": "reference", "w8a8": "xla", "w4a8": "xla"}

DEFAULT_TABLE_PATH = os.path.join(os.path.dirname(__file__), "tuned_table.json")

_TABLE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class KernelChoice:
    """One resolved dispatch decision."""

    backend: str
    # matmul: (BM1, BN1, BK1) kernel blocks (GEMV uses BN1).
    # attn  : (q_chunk, kv_chunk) streaming granularity.
    blocks: tuple[int, ...] | None = None
    source: str = "default"  # "requested" | "tuned" | "default" | "fallback"


def m_bucket(m: int) -> str:
    if m <= 1:
        return "m1"
    if m <= 8:
        return "m8"
    if m <= 32:
        return "m32"
    if m <= 64:
        return "m64"
    return "big"


def dispatch_key(quant: str, phase: Phase, m: int, target_name: str) -> str:
    return f"{quant}|{phase.value}|{m_bucket(m)}|{target_name}"


def default_backend(quant: str, phase: Phase, bucket: str = "") -> str:
    """The static policy — the routing formerly hard-coded across ops.py.

    Decode at GEMV-like row counts ("m1", "m8" — one to a batch of slots)
    takes the fused path (pack/unpack-free, the bandwidth regime's win).
    Past that ("m32": the speculative-decode verify window, slots x
    (draft_k+1) rows; "m64": many-slot decode; "big": the token-budget
    mixed step, slots x window rows when chunked-prefill tokens pack into
    the decode dispatch) the fused GEMV's premise breaks — it keeps the
    whole (M, K) activation block VMEM-resident per streamed weight tile,
    a footprint that grows with M — so multi-row decode routes to the
    packed mmt4d GEMM, the same kernel the prefill slab uses (one verify
    kernel path, TinyIREE's keep-dispatch-small argument).  The policy is
    monotonic in M by design ("big" included — it used to fall through to
    "fused", which silently handed a GEMM-shaped mixed window to the
    row-resident GEMV); a target where the fused GEMV measures faster at
    some bucket says so through its tuned entry (tpu-v5e's m64 entries pin
    "fused"), which outranks this policy.
    Prefill takes the fused GEMM slab for unquantized weights and the
    packed Pallas kernel for quantized ones (their fused slab does not
    exist — the packed kernel already streams int operands).

    This is also what `kernel_bench --tune` records as each entry's backend:
    retuning re-measures blocks against the POLICY backend, never copying a
    backend out of the table being regenerated (a stale entry must not
    self-perpetuate across retunes)."""
    if phase is Phase.DECODE:
        return "pallas" if bucket in ("m32", "m64", "big") else "fused"
    return "fused" if quant == "none" else "pallas"


def _known_key(quant: str, phase: Phase, target_name: str) -> bool:
    known_targets = {targets_lib.TPU_V5E.name, targets_lib.RISCV_VLEN256.name}
    return quant in QUANTS and isinstance(phase, Phase) and target_name in known_targets


# ---- tuned-table persistence ------------------------------------------------

_table_cache: dict[str, dict] = {}


def load_table(path: str | None = None) -> dict:
    """Load (and cache) a tuned table.  Missing/corrupt file -> empty table:
    dispatch falls back to the static policy rather than failing."""
    path = path or DEFAULT_TABLE_PATH
    if path in _table_cache:
        return _table_cache[path]
    table: dict[str, Any] = {"version": _TABLE_VERSION, "entries": {}}
    try:
        with open(path) as f:
            raw = json.load(f)
        if isinstance(raw, dict) and raw.get("version") == _TABLE_VERSION:
            entries = raw.get("entries", {})
            if isinstance(entries, dict):
                table = {"version": _TABLE_VERSION, "entries": entries}
    except (OSError, ValueError):
        pass
    _table_cache[path] = table
    return table


def save_table(table: dict, path: str | None = None) -> str:
    """Persist a tuned table (sorted keys — stable diffs) and refresh the
    cache.  Returns the path written."""
    path = path or DEFAULT_TABLE_PATH
    out = {
        "version": _TABLE_VERSION,
        "entries": dict(sorted(table.get("entries", {}).items())),
    }
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    _table_cache[path] = out
    return path


def clear_cache() -> None:
    """Drop cached tables (tests swap table files underneath the registry)."""
    _table_cache.clear()


# ---- kernel quarantine ------------------------------------------------------
#
# Process-lifetime demotions: dispatch key -> how many rungs of the
# requested -> tuned -> policy -> fallback ladder to skip.  Populated by the
# serving engine when a dispatch raises or fails the finite-output check
# (engine._quarantine_kernel); consulted by select()/select_attn() below.
#
# Tensor-parallel serving makes the table SHARD-AWARE: a fault attributed to
# one shard (a single bad device/core) demotes only that shard's entry —
# stored under "key@shardN" — never the key globally.  Because the serving
# dispatch is one SPMD program executed by every shard, resolution with
# shard=None (what select()/select_attn() do at trace time) takes the MAX
# level over the base key and all its shard entries: the shared program must
# avoid a kernel any shard cannot run.  Per-shard observability
# (Engine.stats["degraded"]/["attn_backend"]) resolves with an explicit
# shard, which consults only that shard's entry (plus any global one) — so a
# shard-0 query stays clean after a shard-1 demotion.

_quarantine: dict[str, dict] = {}

_SHARD_SEP = "@shard"


def _shard_key(key: str, shard: int) -> str:
    return f"{key}{_SHARD_SEP}{int(shard)}"


def quarantine_level(key: str, shard: int | None = None) -> int:
    """Demotion level for `key`.  shard=None: the EFFECTIVE level the single
    SPMD dispatch must honour (max over global + every shard).  shard=k: the
    level as seen from shard k only (global + that shard's entry)."""
    entry = _quarantine.get(key)
    lvl = entry["level"] if entry else 0
    if shard is None:
        prefix = key + _SHARD_SEP
        for k, e in _quarantine.items():
            if k.startswith(prefix):
                lvl = max(lvl, e["level"])
    else:
        e = _quarantine.get(_shard_key(key, shard))
        if e is not None:
            lvl = max(lvl, e["level"])
    return lvl


def quarantine_snapshot() -> dict[str, dict]:
    """{key: {"level", "from", "to", "reason"[, "shard"]}} for every demoted
    key; shard-local demotions appear under their "key@shardN" entry."""
    return {k: dict(v) for k, v in _quarantine.items()}


def clear_quarantine() -> None:
    """Reset all demotions (tests; a real process never un-quarantines)."""
    _quarantine.clear()


def _apply_quarantine(
    key: str, ladder: list[tuple[str, str]], shard: int | None = None
) -> tuple[str, str]:
    """Pick the ladder rung the key's demotion level points at.  Levels past
    the bottom clamp to the last rung (the fallback can't be demoted)."""
    lvl = quarantine_level(key, shard)
    backend, source = ladder[min(lvl, len(ladder) - 1)]
    if lvl > 0:
        source = f"quarantined:{source}"
    return backend, source


def _demote_ladder(key: str, ladder: list[tuple[str, str]], failing: str,
                   reason: str, shard: int | None = None) -> dict:
    """Record a demotion for `key` (shard-local when `shard` is given):
    advance the level until the resolved backend differs from `failing` (or
    the bottom rung is reached).  Returns the quarantine record
    ({"level", "from", "to", "reason"[, "shard"]})."""
    lvl = quarantine_level(key, shard)
    start = min(lvl, len(ladder) - 1)
    new = start
    while new < len(ladder) - 1:
        new += 1
        if ladder[new][0] != failing:
            break
    record = {
        "level": new,
        "from": ladder[start][0],
        "to": ladder[new][0],
        "reason": reason,
    }
    if shard is not None:
        record["shard"] = int(shard)
    _quarantine[key if shard is None else _shard_key(key, shard)] = record
    return record


def _tuned_entry(key: str, path: str | None) -> dict | None:
    entry = load_table(path)["entries"].get(key)
    return entry if isinstance(entry, dict) else None


# ---- the one resolution function -------------------------------------------


def _matmul_ladder(
    quant: str,
    phase: Phase,
    bucket: str,
    target_name: str,
    requested: str | None,
    table_path: str | None,
) -> list[tuple[str, str]]:
    """The full (backend, source) rung list for one matmul key, in resolution
    order.  Rung 0 is what select() returns with no quarantine; demotions
    index further down."""
    valid = BACKENDS_BY_QUANT.get(quant, ())
    ladder: list[tuple[str, str]] = []
    if requested not in (None, "auto"):
        # An explicit backend is a caller decision: a name this quant mode
        # does not understand is a bug at the call site, not a routing
        # question — fail loudly instead of silently running the oracle.
        if requested not in valid:
            raise ValueError(
                f"backend {requested!r} is not valid for quant={quant!r} "
                f"(valid: {valid}); use 'auto' for registry routing"
            )
        ladder.append((requested, "requested"))
    if _known_key(quant, phase, target_name):
        key = f"{quant}|{phase.value}|{bucket}|{target_name}"
        entry = _tuned_entry(key, table_path)
        if entry is not None and entry.get("backend") in valid:
            ladder.append((entry["backend"], "tuned"))
        ladder.append((default_backend(quant, phase, bucket), "default"))
    ladder.append((FALLBACK_BACKEND.get(quant, "reference"), "fallback"))
    return ladder


def select(
    *,
    quant: str,
    phase: Phase,
    m: int,
    target: targets_lib.TargetSpec = targets_lib.TPU_V5E,
    requested: str | None = None,
    blocks: tuple[int, int, int] | None = None,
    table_path: str | None = None,
    shard: int | None = None,
) -> KernelChoice:
    """Resolve one dispatch.  `requested` is the caller's backend= argument:
    "auto"/None defer to the registry; anything else is honoured verbatim
    (still picking up tuned blocks when the caller passed none) — unless the
    key is quarantined, which outranks even an explicit request (a pinned
    kernel that failed the finite check must not keep serving).  `shard`
    scopes the quarantine lookup: None = the effective (SPMD) level, k =
    shard k's own view (per-shard observability)."""
    key = dispatch_key(quant, phase, m, getattr(target, "name", str(target)))
    entry = _tuned_entry(key, table_path)
    tuned_blocks = None
    if entry is not None and isinstance(entry.get("blocks"), (list, tuple)):
        b = entry["blocks"]
        if len(b) == 3 and all(isinstance(v, int) and v >= 1 for v in b):
            tuned_blocks = (b[0], b[1], b[2])
    resolved_blocks = blocks if blocks is not None else tuned_blocks

    ladder = _matmul_ladder(
        quant, phase, m_bucket(m), getattr(target, "name", str(target)),
        requested, table_path,
    )
    backend, source = _apply_quarantine(key, ladder, shard)
    if source == "fallback":
        resolved_blocks = (
            None if quarantine_level(key, shard) == 0 else resolved_blocks
        )
    return KernelChoice(backend, resolved_blocks, source)


def resolve_key(
    key: str,
    *,
    requested: str | None = None,
    table_path: str | None = None,
    shard: int | None = None,
) -> KernelChoice:
    """Resolve a dispatch key string directly (either op class) — what
    select()/select_attn() would return for it, quarantine included.  The
    serving engine uses this to learn which backend is CURRENTLY serving a
    key before demoting it, and (with `shard`) to report per-shard
    resolution in stats."""
    op = key.split("|", 1)[0]
    if op == ATTN_OP:
        phase_val, bucket, kv, target_name = split_attn_key(key)
        ladder = _attn_ladder(
            Phase(phase_val), bucket, kv, target_name, requested, table_path
        )
    else:
        op, phase_val, bucket, target_name = key.split("|", 3)
        ladder = _matmul_ladder(
            op, Phase(phase_val), bucket, target_name, requested, table_path
        )
    backend, source = _apply_quarantine(key, ladder, shard)
    return KernelChoice(backend, None, source)


def demote(
    key: str,
    *,
    failing: str,
    reason: str = "",
    requested: str | None = None,
    table_path: str | None = None,
    shard: int | None = None,
) -> dict:
    """Quarantine `key` (either op class — the key string carries its class):
    advance its demotion level past every rung that would re-resolve to the
    `failing` backend.  With `shard`, the demotion is SHARD-LOCAL (stored
    under "key@shardN"): other shards' views stay clean, though the shared
    SPMD dispatch honours the max level across shards.  Idempotent per rung:
    demoting an already-demoted key moves it further down; the bottom rung
    clamps.  Returns the quarantine record the engine surfaces in
    stats["degraded"]."""
    op = key.split("|", 1)[0]
    if op == ATTN_OP:
        phase_val, bucket, kv, target_name = split_attn_key(key)
        ladder = _attn_ladder(
            Phase(phase_val), bucket, kv, target_name, requested, table_path
        )
    else:
        op, phase_val, bucket, target_name = key.split("|", 3)
        ladder = _matmul_ladder(
            op, Phase(phase_val), bucket, target_name, requested, table_path
        )
    return _demote_ladder(key, ladder, failing, reason, shard)


# ---- the attention op class -------------------------------------------------

ATTN_OP = "attn"

# "xla" is the jnp reference pair (layers.attention_decode /
# attention_chunked) — also the no-data fallback; "pallas" is kernels/attn.py.
ATTN_BACKENDS = ("xla", "pallas")
ATTN_FALLBACK_BACKEND = "xla"

S_BUCKETS = ("s256", "s1k", "s4k", "sbig")

# KV-cache storage layouts forming the third attn-key axis (the canonical
# tuple lives with the KVLayout codec in core/encoding.py).
KV_QUANTS = encoding.KV_QUANTS


def s_bucket(s: int) -> str:
    """Context-length bucket: the logical KV length the dispatch attends."""
    if s <= 256:
        return "s256"
    if s <= 1024:
        return "s1k"
    if s <= 4096:
        return "s4k"
    return "sbig"


def attn_dispatch_key(
    phase: Phase, s: int, target_name: str, kv: str = "bf16"
) -> str:
    """Attention dispatch key.  bf16 emits the legacy 4-segment form
    (backward-compatible with every checked-in tuned entry and fault
    pattern); kv8/kv4 insert the kv-quant axis before the target."""
    if kv in (None, "bf16"):
        return f"{ATTN_OP}|{phase.value}|{s_bucket(s)}|{target_name}"
    if kv not in encoding.KV_QUANTS:
        raise ValueError(
            f"unknown kv_quant {kv!r}; expected one of {encoding.KV_QUANTS}"
        )
    return f"{ATTN_OP}|{phase.value}|{s_bucket(s)}|{kv}|{target_name}"


def split_attn_key(key: str) -> tuple[str, str, str, str]:
    """attn key -> (phase value, S-bucket, kv-quant, target name).  Accepts
    both the legacy 4-segment form (implied kv=bf16) and the 5-segment
    kv-quant form."""
    parts = key.split("|")
    if parts[0] != ATTN_OP:
        raise ValueError(f"not an attn key: {key!r}")
    if len(parts) == 4:
        return parts[1], parts[2], "bf16", parts[3]
    if len(parts) == 5 and parts[3] in encoding.KV_QUANTS:
        return parts[1], parts[2], parts[3], parts[4]
    raise ValueError(f"malformed attn key: {key!r}")


def default_attn_backend(phase: Phase, bucket: str = "") -> str:
    """Static attention policy: every phase of a known target takes the
    Pallas kernel — decode because the paged kernel streams only the slot's
    live pages (no materialized `paged_gather` view) and the dense kernel
    bounds its chunk scan at the newest written slot; prefill because the
    flash kernel skips upper-triangle KV chunks the reference visits-and-
    masks.  There is no S-bucket below which the reference wins on traffic
    (the gather view costs O(pool) at every context length), so the policy
    is constant; a target where the reference measures faster at some bucket
    says so through its tuned entry, which outranks this."""
    return "pallas"


def _attn_tuned_blocks(entry: dict | None) -> tuple[int, ...] | None:
    if entry is None or not isinstance(entry.get("blocks"), (list, tuple)):
        return None
    b = entry["blocks"]
    if len(b) in (2, 3) and all(isinstance(v, int) and v >= 1 for v in b):
        return tuple(b[:2])  # (q_chunk, kv_chunk)
    return None


def _attn_tuned_lookup(
    phase: Phase, bucket: str, kv: str, target_name: str, table_path: str | None
) -> dict | None:
    """Tuned entry for an attn key: the exact (possibly 5-segment) key
    first; a kv-quant key with no entry of its own inherits the legacy bf16
    entry — blocks are chunk geometry, independent of the streamed dtype,
    so a fresh kv axis never silently loses the measured chunking."""
    key = f"{ATTN_OP}|{phase.value}|{bucket}|{target_name}"
    if kv not in (None, "bf16"):
        exact = _tuned_entry(
            f"{ATTN_OP}|{phase.value}|{bucket}|{kv}|{target_name}", table_path
        )
        if exact is not None:
            return exact
    return _tuned_entry(key, table_path)


def _attn_ladder(
    phase: Phase,
    bucket: str,
    kv: str,
    target_name: str,
    requested: str | None,
    table_path: str | None,
) -> list[tuple[str, str]]:
    """The (backend, source) rung list for one attention key — the attn
    op-class analogue of _matmul_ladder."""
    ladder: list[tuple[str, str]] = []
    if requested not in (None, "auto"):
        if requested not in ATTN_BACKENDS:
            raise ValueError(
                f"attention backend {requested!r} is not valid "
                f"(valid: {ATTN_BACKENDS}); use 'auto' for registry routing"
            )
        ladder.append((requested, "requested"))
    known_targets = {targets_lib.TPU_V5E.name, targets_lib.RISCV_VLEN256.name}
    if isinstance(phase, Phase) and target_name in known_targets:
        entry = _attn_tuned_lookup(phase, bucket, kv, target_name, table_path)
        if entry is not None and entry.get("backend") in ATTN_BACKENDS:
            ladder.append((entry["backend"], "tuned"))
        ladder.append((default_attn_backend(phase, bucket), "default"))
    ladder.append((ATTN_FALLBACK_BACKEND, "fallback"))
    return ladder


def select_attn(
    *,
    phase: Phase,
    s: int,
    target: targets_lib.TargetSpec = targets_lib.TPU_V5E,
    requested: str | None = None,
    blocks: tuple[int, ...] | None = None,
    table_path: str | None = None,
    shard: int | None = None,
    kv: str = "bf16",
) -> KernelChoice:
    """Resolve one attention dispatch — the second op class, mirroring
    select(): `requested` is the caller's attn_backend (EncodingConfig /
    serve_llama --attn-backend); "auto"/None defer to tuned table -> static
    policy -> "xla" fallback on unknown targets.  A quarantined key outranks
    everything, including an explicit request; `shard` scopes the lookup as
    in select().  `kv` is the KV-cache storage layout axis: quarantine and
    tuning are tracked per kv-quant key (a kernel that fails on int4 pages
    must not quarantine the bf16 path), with tuned blocks inherited from
    the bf16 entry when the kv-quant key has none of its own."""
    target_name = getattr(target, "name", str(target))
    key = attn_dispatch_key(phase, s, target_name, kv)
    bucket = s_bucket(s) if isinstance(phase, Phase) else ""
    entry = _attn_tuned_lookup(phase, bucket, kv, target_name, table_path)
    resolved_blocks = blocks if blocks is not None else _attn_tuned_blocks(entry)

    ladder = _attn_ladder(phase, bucket, kv, target_name, requested, table_path)
    backend, source = _apply_quarantine(key, ladder, shard)
    if source == "fallback" and quarantine_level(key, shard) == 0:
        resolved_blocks = None
    return KernelChoice(backend, resolved_blocks, source)
