"""llama-3.2-1b — the paper's own evaluation model (Llama-3.2-1B-Instruct).

Used by benchmarks/table1_parity.py and table2_throughput.py to mirror the
paper's Tables 1-2.  [hf:meta-llama/Llama-3.2-1B-Instruct]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=5e5,
    tie_embeddings=True,
    sub_quadratic=False,
)
