"""rwkv6-1.6b [ssm] — Finch, data-dependent decay, attention-free.
[arXiv:2404.05892; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv",
    num_layers=24,
    d_model=2048,
    num_heads=32,            # d_model / rwkv_head_dim
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
    norm_kind="layernorm",
    sub_quadratic=True,      # O(1)-state decode -> long_500k runs
)
