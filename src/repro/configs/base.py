"""Model/runtime configuration system.

One `ModelConfig` per assigned architecture lives in src/repro/configs/<id>.py;
`reduced()` derives the CPU smoke-test variant of the same family.  Shapes are
separate (`ShapeConfig`, configs/shapes.py) so every (arch x shape) dry-run
cell is `(ModelConfig, ShapeConfig)`.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # MoE.
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # Attention.
    qkv_bias: bool = False
    sliding_window: int = 0           # 0 = full attention
    rope_theta: float = 1e4
    # Hybrid/RWKV.
    block_pattern: tuple[str, ...] = ("attn",)  # e.g. ("rec","rec","attn")
    rnn_width: int = 0                # RG-LRU recurrent width (0 = d_model)
    conv_width: int = 4               # RG-LRU temporal conv
    rwkv_head_dim: int = 64
    # Enc-dec / multimodal frontends (stubs provide precomputed embeddings).
    encoder_layers: int = 0
    frontend: str = "none"            # none | audio | vision
    frontend_tokens: int = 0          # encoder frames / image patches
    frontend_dim: int = 0             # raw frontend embedding dim
    max_pos_embed: int = 32768        # learned-pos table size (enc-dec only)
    # Numerics.
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    norm_kind: str = "rmsnorm"        # rmsnorm | layernorm
    mlp_kind: str = "swiglu"          # swiglu | gelu
    # Long-context capability (True for SSM/hybrid/SWA archs; gates long_500k).
    sub_quadratic: bool = False
    # Chunk sizes for memory-efficient attention / recurrent scan.
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # --- beyond-paper perf levers (EXPERIMENTS.md §Perf) ---
    # Expand KV heads to the query-head count inside attention so score/value
    # contractions shard over the full TP axis (GQA kv_heads < TP degree
    # otherwise forces partial replication).
    tp_attn_expand_kv: bool = False
    # With expand_kv: zero-pad the flat head axis up to a multiple of this so
    # it divides the TP axis (e.g. qwen's 40 heads -> 48); padded-head outputs
    # are sliced off before W_o.  0 = off.
    pad_attn_heads_to: int = 0
    # MoE dispatch in G independent token groups (set to the DP degree):
    # ranking/scatter become group-local, so SPMD never reshards the (T, k, D)
    # dispatch tensors across the mesh.  Capacity is enforced per group.
    # 0 = global dispatch (paper-faithful single queue).
    moe_dispatch_groups: int = 0
    # Dispatch+combine under shard_map over the DP axes: scatter/gather are
    # guaranteed shard-local (GSPMD cannot misplace them), expert FFNs stay in
    # auto-SPMD so TP weight sharding is preserved.  Falls back to the plain
    # path when no mesh is ambient (CPU tests) or tokens don't divide.
    moe_shard_map: bool = False
    # Decode-phase MoE without dispatch: run every expert on the (few) live
    # tokens and combine with gate weights.  At decode T is tiny, so the extra
    # FLOPs are negligible while all dispatch collectives disappear.
    moe_dense_decode: bool = False
    # Split causal attention into static bands; band b only scans KV chunks
    # up to its own end, skipping the always-masked upper triangle.
    # 1 = off (full rectangle); 4 cuts causal-attn FLOPs to ~0.625x.
    causal_bands: int = 1

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def gqa_groups(self) -> int:
        return max(1, self.num_heads // max(self.num_kv_heads, 1))

    def param_count(self) -> int:
        """Analytic parameter count (unpadded) for 6ND roofline accounting."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        per_layer = 0
        n_attn, n_rec, n_rwkv = 0, 0, 0
        pat = self.block_pattern
        for i in range(self.num_layers):
            t = pat[i % len(pat)]
            if t == "attn":
                n_attn += 1
            elif t == "rec":
                n_rec += 1
            elif t == "rwkv":
                n_rwkv += 1
        attn_p = d * hd * (h + 2 * kv) + h * hd * d
        if self.num_experts:
            ffn_p = self.num_experts * 3 * d * f + d * self.num_experts
        elif self.mlp_kind == "swiglu":
            ffn_p = 3 * d * f
        else:
            ffn_p = 2 * d * f
        rnn_w = self.rnn_width or d
        rec_p = 2 * d * rnn_w + rnn_w * d + self.conv_width * rnn_w + 2 * rnn_w
        rwkv_p = 6 * d * d + 2 * d * f  # r,k,v,g,o,w-lora + channel-mix
        per_layer = n_attn * (attn_p + ffn_p) + n_rec * (rec_p + ffn_p) + n_rwkv * rwkv_p
        emb = v * d * (1 if self.tie_embeddings else 2)
        enc = self.encoder_layers * (attn_p + ffn_p)
        return per_layer + emb + enc

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6*N_active*D roofline)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        total = self.param_count()
        moe_all = self.num_layers * self.num_experts * 3 * d * f
        moe_active = self.num_layers * self.experts_per_token * 3 * d * f
        return total - moe_all + moe_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides: Any) -> ModelConfig:
    """CPU smoke-test variant: same family/topology, tiny dims."""
    pat_len = len(cfg.block_pattern)
    small = dict(
        # Keep the layer-count remainder so the partial tail group (e.g.
        # recurrentgemma's 38 = 12*3 + 2) is exercised by smoke tests too.
        num_layers=max(2, 2 * pat_len) + cfg.num_layers % pat_len,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, 4 // max(1, cfg.gqa_groups)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        rnn_width=64 if cfg.rnn_width else 0,
        rwkv_head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_tokens=8 if cfg.frontend_tokens else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        dtype="float32",
        q_chunk=8,
        kv_chunk=8,
        name=cfg.name + "-reduced",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
