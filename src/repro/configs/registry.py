"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, reduced

_ARCH_MODULES = {
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "yi-9b": "repro.configs.yi_9b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    # The paper's own model (not part of the assigned 10).
    "llama3.2-1b": "repro.configs.llama3_2_1b",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if k != "llama3.2-1b")


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_reduced(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Dry-run cell gating (skips documented in DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (full-attention arch)"
    return True, ""


def all_cells() -> list[tuple[str, str, bool, str]]:
    """[(arch, shape, runnable, reason)] for the 40-cell grid."""
    out = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_is_runnable(cfg, shape)
            out.append((arch, shape.name, ok, why))
    return out
