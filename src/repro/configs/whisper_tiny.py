"""whisper-tiny [audio] — enc-dec, conv frontend STUB (precomputed frame
embeddings).  [arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,            # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    block_pattern=("encdec_attn",),
    norm_kind="layernorm",
    mlp_kind="gelu",
    frontend="audio",
    frontend_tokens=1500,    # 30 s of audio at 50 Hz post-conv
    sub_quadratic=False,     # full-attention decoder -> long_500k skipped
)
