"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 rec.
[arXiv:2402.19427; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,          # MQA on the local-attention layers
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    sliding_window=2048,     # local attention window
    rnn_width=4096,
    conv_width=4,
    tie_embeddings=True,
    sub_quadratic=True,      # bounded state -> long_500k runs
)
