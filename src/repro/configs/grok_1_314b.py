"""grok-1-314b [moe] — 8 experts top-2, GQA kv=8, full attention.
[hf:xai-org/grok-1; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_token=2,
    rope_theta=1e4,
    sub_quadratic=False,  # full attention -> long_500k skipped (DESIGN.md §4)
)
