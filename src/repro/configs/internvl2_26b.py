"""internvl2-26b [vlm] — InternViT frontend STUB (precomputed patch
embeddings) + InternLM2-20B language backbone.  [arXiv:2404.16821; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1e6,
    frontend="vision",
    frontend_tokens=256,     # one tile of InternViT patches after pixel-shuffle
    frontend_dim=3200,       # InternViT-6B width
    sub_quadratic=False,
)
