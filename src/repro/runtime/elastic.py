"""Elastic scaling: rebuild the mesh for the surviving device count and
reshard training state from the latest checkpoint.

Flow on failure (driver loop in launch/train.py):
  1. watchdog evicts host(s) / jax reports lost devices,
  2. `plan(devices)` picks the largest usable (data, model) grid,
  3. state restores from the last checkpoint with the new shardings
     (checkpoint leaves are stored unsharded — see checkpoint.py),
  4. the data pipeline re-keys on the new (host_id, num_hosts),
  5. training resumes at the checkpointed step: no progress loss beyond the
     checkpoint interval.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.checkpoint import checkpoint as ckpt_lib
from repro.launch import mesh as mesh_lib
from repro.parallel import sharding


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    devices: int
    data: int
    model: int

    def make_mesh(self):
        return mesh_lib.make_mesh_for(self.devices, model_parallel=self.model)


def plan(devices: int, *, prefer_model_parallel: int = 16) -> ElasticPlan:
    """Largest (data, model) grid for `devices`, preferring the production TP
    degree, falling back to smaller powers that divide."""
    mp = min(prefer_model_parallel, devices)
    while devices % mp:
        mp -= 1
    return ElasticPlan(devices=devices, data=devices // mp, model=mp)


def resume(ckpt_dir: str, like_state, new_mesh):
    """Restore the latest checkpoint resharded onto `new_mesh`."""
    step = ckpt_lib.latest_step(ckpt_dir)
    if step is None:
        return None, None
    sh = {
        "params": sharding.params_shardings(like_state["params"], new_mesh),
        "opt": {
            "mu": sharding.params_shardings(like_state["opt"]["mu"], new_mesh),
            "nu": sharding.params_shardings(like_state["opt"]["nu"], new_mesh),
            "step": sharding.replicated(new_mesh),
        },
    }
    state = ckpt_lib.restore(ckpt_dir, step, like_state, shardings=sh)
    return state, step
