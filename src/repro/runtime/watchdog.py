"""Straggler detection & mitigation.

Per-step wall times feed an EWMA; a host whose step exceeds
`threshold x EWMA` is flagged.  Mitigation is pluggable: the trainer installs
a callback that (a) logs, (b) reassigns the straggler's data shards to healthy
hosts via `DataReassigner` (the synthetic pipeline is keyed by (host, shard)
so reassignment is just arithmetic), and (c) after `evict_after` consecutive
flags, requests an elastic re-mesh (runtime/elastic.py).

Clock is injectable so tests drive it deterministically.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class WatchdogConfig:
    ewma_alpha: float = 0.2
    threshold: float = 2.5
    warmup_steps: int = 5
    evict_after: int = 3


class StepWatchdog:
    def __init__(self, cfg: WatchdogConfig = WatchdogConfig(), *, clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.ewma: float | None = None
        self.steps = 0
        self._start: float | None = None
        self.flags: dict[int, int] = {}       # host -> consecutive flags
        self.evicted: set[int] = set()

    def step_start(self):
        self._start = self.clock()

    def step_end(self, *, host_times: dict[int, float] | None = None) -> list[int]:
        """Returns hosts flagged this step.  host_times: per-host durations
        (from an all-gather of step times in a real deployment; injected in
        tests).  Without per-host times, only the global EWMA updates."""
        assert self._start is not None
        dur = self.clock() - self._start
        self._start = None
        self.steps += 1
        if self.ewma is None:
            self.ewma = dur
        else:
            a = self.cfg.ewma_alpha
            self.ewma = a * dur + (1 - a) * self.ewma

        flagged = []
        if host_times and self.steps > self.cfg.warmup_steps:
            for host, t in host_times.items():
                if host in self.evicted:
                    continue
                if t > self.cfg.threshold * self.ewma:
                    self.flags[host] = self.flags.get(host, 0) + 1
                    flagged.append(host)
                    if self.flags[host] >= self.cfg.evict_after:
                        self.evicted.add(host)
                else:
                    self.flags[host] = 0
        return flagged

    def should_remesh(self) -> bool:
        return bool(self.evicted)


class DataReassigner:
    """Maps logical data shards to surviving hosts after eviction."""

    def __init__(self, num_hosts: int):
        self.num_hosts = num_hosts
        self.assignment = {h: [h] for h in range(num_hosts)}  # host -> shards

    def evict(self, host: int):
        if host not in self.assignment:
            return
        orphaned = self.assignment.pop(host)
        survivors = sorted(self.assignment)
        for i, shard in enumerate(orphaned):
            target = survivors[i % len(survivors)]
            self.assignment[target].append(shard)

    def shards_for(self, host: int) -> list[int]:
        return sorted(self.assignment.get(host, []))
