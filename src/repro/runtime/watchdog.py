"""Straggler detection & mitigation — and the serving decode-step watchdog.

Per-step wall times feed an EWMA; a host whose step exceeds
`threshold x EWMA` is flagged.  Mitigation is pluggable: the trainer installs
a callback that (a) logs, (b) reassigns the straggler's data shards to healthy
hosts via `DataReassigner` (the synthetic pipeline is keyed by (host, shard)
so reassignment is just arithmetic), and (c) after `evict_after` consecutive
flags, requests an elastic re-mesh (runtime/elastic.py).

`DecodeStepWatchdog` promotes the same EWMA machinery into the serving
engine's step loop (serving/engine.py wires it into Engine.stats): per-step
latency EWMA, stall detection (a step slower than `threshold x EWMA` after
warmup), and p50/p99 over a bounded recent-step window.  A stalled decode
stream is the first symptom of every fault class the chaos harness injects
(pool livelock, quarantine recompile storms, clock skew), so the watchdog is
the observable the degradation ladder is judged by (docs/ROBUSTNESS.md).

Clock is injectable so tests drive it deterministically.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass
class WatchdogConfig:
    ewma_alpha: float = 0.2
    threshold: float = 2.5
    warmup_steps: int = 5
    evict_after: int = 3


class StepWatchdog:
    def __init__(self, cfg: WatchdogConfig = WatchdogConfig(), *, clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.ewma: float | None = None
        self.steps = 0
        self._start: float | None = None
        self.flags: dict[int, int] = {}       # host -> consecutive flags
        self.evicted: set[int] = set()

    def step_start(self):
        self._start = self.clock()

    def step_end(self, *, host_times: dict[int, float] | None = None) -> list[int]:
        """Returns hosts flagged this step.  host_times: per-host durations
        (from an all-gather of step times in a real deployment; injected in
        tests).  Without per-host times, only the global EWMA updates."""
        assert self._start is not None
        dur = self.clock() - self._start
        self._start = None
        self.steps += 1
        if self.ewma is None:
            self.ewma = dur
        else:
            a = self.cfg.ewma_alpha
            self.ewma = a * dur + (1 - a) * self.ewma

        flagged = []
        if host_times and self.steps > self.cfg.warmup_steps:
            for host, t in host_times.items():
                if host in self.evicted:
                    continue
                if t > self.cfg.threshold * self.ewma:
                    self.flags[host] = self.flags.get(host, 0) + 1
                    flagged.append(host)
                    if self.flags[host] >= self.cfg.evict_after:
                        self.evicted.add(host)
                else:
                    self.flags[host] = 0
        return flagged

    def should_remesh(self) -> bool:
        return bool(self.evicted)


class DecodeStepWatchdog:
    """Serving-side step watchdog: EWMA + stall flags + latency percentiles.

    One instance per Engine.  `step_start()` / `step_end()` bracket each
    engine step (step_end is exception-safe via try/finally in the engine
    loop); `summary()` is merged into Engine.stats["watchdog"].  `window`
    bounds the percentile buffer so a long-lived engine never grows state.
    """

    def __init__(
        self,
        cfg: WatchdogConfig = WatchdogConfig(),
        *,
        clock: Callable[[], float] = time.monotonic,
        window: int = 512,
    ):
        self.cfg = cfg
        self.clock = clock
        self.ewma: float | None = None
        self.steps = 0
        self.stalls = 0
        self.last_stalled = False
        self.last_duration: float = 0.0
        self._start: float | None = None
        self._recent: collections.deque[float] = collections.deque(maxlen=window)

    def step_start(self) -> None:
        self._start = self.clock()

    def step_end(self) -> bool:
        """Record one step; returns True when this step counts as a stall
        (post-warmup step slower than threshold x the running EWMA)."""
        if self._start is None:
            return False  # step_start never ran (exception before the bracket)
        dur = max(self.clock() - self._start, 0.0)
        self._start = None
        self.steps += 1
        self.last_duration = dur
        self._recent.append(dur)
        stalled = (
            self.steps > self.cfg.warmup_steps
            and self.ewma is not None
            and dur > self.cfg.threshold * self.ewma
        )
        if stalled:
            self.stalls += 1
            # A stall is an outlier by definition: folding it into the EWMA
            # at full weight would teach the watchdog that stalls are normal.
            # Clamp the sample to the flag threshold before updating.
            dur = self.cfg.threshold * self.ewma
        self.last_stalled = bool(stalled)
        if self.ewma is None:
            self.ewma = dur
        else:
            a = self.cfg.ewma_alpha
            self.ewma = a * dur + (1 - a) * self.ewma
        return bool(stalled)

    def percentile(self, q: float) -> float:
        if not self._recent:
            return 0.0
        return float(np.percentile(np.asarray(self._recent), q))

    def summary(self) -> dict:
        return {
            "steps": self.steps,
            "ewma_ms": 1e3 * (self.ewma or 0.0),
            "last_ms": 1e3 * self.last_duration,
            "p50_ms": 1e3 * self.percentile(50),
            "p99_ms": 1e3 * self.percentile(99),
            "stalls": self.stalls,
            "stalled": self.last_stalled,
        }


class DataReassigner:
    """Maps logical data shards to surviving hosts after eviction."""

    def __init__(self, num_hosts: int):
        self.num_hosts = num_hosts
        self.assignment = {h: [h] for h in range(num_hosts)}  # host -> shards

    def evict(self, host: int):
        if host not in self.assignment:
            return
        orphaned = self.assignment.pop(host)
        survivors = sorted(self.assignment)
        for i, shard in enumerate(orphaned):
            target = survivors[i % len(survivors)]
            self.assignment[target].append(shard)

    def shards_for(self, host: int) -> list[int]:
        return sorted(self.assignment.get(host, []))
